//! H2O (Heavy-Hitter Oracle) baseline.
//!
//! Keeps a token budget split between (a) *heavy hitters* — tokens with the
//! largest cumulative attention mass — and (b) the most recent tokens.
//! Attention mass is seeded from the prefill pass
//! ([`crate::kvcache::KvCachePolicy::observe_prefill_attn`]) and updated
//! every decode step, exactly the greedy eviction of Zhang et al. (2023).
//! Like the paper's evaluation we aggregate scores across heads (the
//! official implementation evicts per-head; aggregate eviction is the
//! standard architecture-agnostic variant — DESIGN.md §2).

use crate::tensor::Mat;

use crate::kvcache::snapshot::{self, tags, SnapReader, SnapWriter};
use crate::kvcache::{CacheView, DecodeView, GrowMat, KvCachePolicy, KvSnapshot};

pub struct H2oCache {
    budget: usize,
    /// Recent tokens protected from eviction (half the budget, per paper).
    recent: usize,
    layers: Vec<LayerState>,
}

struct LayerState {
    k: GrowMat,
    v: GrowMat,
    abs_pos: Vec<usize>,
    score: Vec<f32>,
    n: usize,
    /// Cumulative eviction count — synced views record it as their epoch.
    evictions: usize,
    /// Recent evictions as (ordinal, kept-list index) pairs, capped at
    /// [`EVICT_LOG_CAP`]. Lets any view compute the lowest row disturbed
    /// since its own last sync; views that fell further behind than the
    /// log reaches rebuild fully.
    evict_log: std::collections::VecDeque<(usize, usize)>,
}

/// Eviction-log depth: one eviction happens per appended token once at
/// budget, so this covers views up to 128 tokens stale.
const EVICT_LOG_CAP: usize = 128;

impl H2oCache {
    pub fn new(n_layers: usize, d_model: usize, budget: usize) -> Self {
        assert!(budget >= 2);
        H2oCache {
            budget,
            recent: budget / 2,
            layers: (0..n_layers)
                .map(|_| LayerState {
                    k: GrowMat::new(d_model),
                    v: GrowMat::new(d_model),
                    abs_pos: Vec::new(),
                    score: Vec::new(),
                    n: 0,
                    evictions: 0,
                    evict_log: std::collections::VecDeque::new(),
                })
                .collect(),
        }
    }

    fn evict(&mut self, layer: usize) {
        let budget = self.budget;
        let recent = self.recent;
        let l = &mut self.layers[layer];
        while l.abs_pos.len() > budget {
            // Lowest cumulative score among non-recent entries.
            let cutoff = l.abs_pos.len() - recent;
            let mut worst = 0;
            let mut worst_score = f32::INFINITY;
            for i in 0..cutoff {
                if l.score[i] < worst_score {
                    worst_score = l.score[i];
                    worst = i;
                }
            }
            l.k.remove_row(worst);
            l.v.remove_row(worst);
            l.abs_pos.remove(worst);
            l.score.remove(worst);
            l.evictions += 1;
            l.evict_log.push_back((l.evictions, worst));
            if l.evict_log.len() > EVICT_LOG_CAP {
                l.evict_log.pop_front();
            }
        }
    }
}

impl KvCachePolicy for H2oCache {
    fn name(&self) -> String {
        format!("h2o(budget={})", self.budget)
    }

    fn ingest_prefill(&mut self, layer: usize, _xnorm: &Mat, k: &Mat, v: &Mat) -> Option<(Mat, Mat)> {
        let l = &mut self.layers[layer];
        l.k.push_mat(k);
        l.v.push_mat(v);
        l.abs_pos.extend(0..k.rows);
        l.score.extend(std::iter::repeat(0.0).take(k.rows));
        l.n = k.rows;
        // Eviction is deferred to observe_prefill_attn so scores exist.
        None
    }

    fn observe_prefill_attn(&mut self, layer: usize, mass: &[f32]) {
        {
            let l = &mut self.layers[layer];
            debug_assert_eq!(mass.len(), l.score.len());
            for (s, &m) in l.score.iter_mut().zip(mass) {
                *s += m;
            }
        }
        self.evict(layer);
    }

    fn append(&mut self, layer: usize, _xnorm: &[f32], k: &[f32], v: &[f32]) {
        {
            let l = &mut self.layers[layer];
            let pos = l.n;
            l.k.push_row(k);
            l.v.push_row(v);
            l.abs_pos.push(pos);
            l.score.push(0.0);
            l.n += 1;
        }
        self.evict(layer);
    }

    fn sync_view(&mut self, layer: usize, view: &mut DecodeView) {
        let l = &self.layers[layer];
        let kept = l.abs_pos.len();
        // Rows below the first index disturbed since this view's last
        // sync kept their position and contents; everything after is
        // rewritten. A view with no missed evictions only appends.
        let start = if view.epoch == l.evictions {
            view.len().min(kept)
        } else {
            let covered = view.epoch < l.evictions
                && l.evict_log
                    .front()
                    .is_some_and(|&(ordinal, _)| ordinal <= view.epoch + 1);
            if covered {
                let mut lo = usize::MAX;
                for &(ordinal, idx) in &l.evict_log {
                    if ordinal > view.epoch {
                        lo = lo.min(idx);
                    }
                }
                lo.min(view.len()).min(kept)
            } else {
                // Stale beyond the log (or foreign view): full rebuild.
                0
            }
        };
        view.truncate(start);
        for i in start..kept {
            // H2O keeps original (absolute) positions.
            view.write_row(i, l.k.row(i), l.v.row(i), l.abs_pos[i], l.abs_pos[i]);
        }
        view.epoch = l.evictions;
    }

    fn materialize(&self, layer: usize) -> CacheView {
        let l = &self.layers[layer];
        CacheView {
            k: l.k.to_mat(),
            v: l.v.to_mat(),
            // H2O keeps original (absolute) positions.
            rope_pos: l.abs_pos.clone(),
            abs_pos: l.abs_pos.clone(),
        }
    }

    fn reserve(&mut self, additional_tokens: usize) {
        for l in &mut self.layers {
            let extra = additional_tokens.min(self.budget + 1);
            l.k.reserve_rows(extra);
            l.v.reserve_rows(extra);
        }
    }

    fn observe_decode_attn(&mut self, layer: usize, abs_pos: &[usize], probs: &[f32]) {
        let l = &mut self.layers[layer];
        debug_assert_eq!(abs_pos.len(), probs.len());
        // abs_pos here mirrors materialize() order, which is l.abs_pos.
        for (i, &p) in probs.iter().enumerate() {
            if i < l.score.len() {
                debug_assert_eq!(l.abs_pos[i], abs_pos[i]);
                l.score[i] += p;
            }
        }
    }

    fn attention_profile(&self) -> Option<Vec<f32>> {
        // Accumulated mass per absolute token position, summed across
        // layers. Positions this cache already evicted carry 0.0 — they
        // cost nothing to park cold, which is exactly the signal the
        // pager wants.
        let tokens = self.layers.iter().map(|l| l.n).max().unwrap_or(0);
        if tokens == 0 {
            return None;
        }
        let mut mass = vec![0.0f32; tokens];
        for l in &self.layers {
            for (&pos, &s) in l.abs_pos.iter().zip(&l.score) {
                if pos < tokens {
                    mass[pos] += s;
                }
            }
        }
        Some(mass)
    }

    fn len(&self, layer: usize) -> usize {
        self.layers[layer].abs_pos.len()
    }

    fn kv_bytes(&self) -> usize {
        self.layers
            .iter()
            // score vector is bookkeeping, but charge it honestly anyway
            .map(|l| l.k.bytes() + l.v.bytes() + l.score.len() * 4)
            .sum()
    }

    fn kv_bytes_projected(&self, tokens: usize) -> usize {
        // Eviction caps storage (and the score vector) at the budget.
        let kept = tokens.min(self.budget);
        self.layers
            .iter()
            .map(|l| 4 * kept * (l.k.cols + l.v.cols) + 4 * kept)
            .sum()
    }

    fn snapshot(&self) -> KvSnapshot {
        let mut w = SnapWriter::new();
        w.write_usize(self.budget);
        w.write_usize(self.recent);
        w.write_usize(self.layers.len());
        for l in &self.layers {
            snapshot::write_growmat(&mut w, &l.k);
            snapshot::write_growmat(&mut w, &l.v);
            w.usizes(&l.abs_pos);
            w.f32s(&l.score);
            w.write_usize(l.n);
            w.write_usize(l.evictions);
            // Eviction log: lets a restored policy keep serving stale
            // views exactly as the original would have.
            w.write_usize(l.evict_log.len());
            for &(ordinal, idx) in &l.evict_log {
                w.write_usize(ordinal);
                w.write_usize(idx);
            }
        }
        KvSnapshot::new(tags::H2O, w.finish())
    }

    fn restore(&mut self, snap: &KvSnapshot) -> anyhow::Result<()> {
        snap.expect_tag(tags::H2O, "h2o cache")?;
        let mut r = SnapReader::new(snap.payload());
        let budget = r.read_usize()?;
        let recent = r.read_usize()?;
        anyhow::ensure!(
            budget == self.budget && recent == self.recent,
            "h2o cache: snapshot budget {budget}/{recent} != target {}/{}",
            self.budget,
            self.recent
        );
        let n_layers = r.read_usize()?;
        anyhow::ensure!(
            n_layers == self.layers.len(),
            "h2o cache: snapshot has {n_layers} layers, target {}",
            self.layers.len()
        );
        for l in &mut self.layers {
            let k = snapshot::read_growmat(&mut r)?;
            let v = snapshot::read_growmat(&mut r)?;
            let abs_pos = r.usizes()?;
            let score = r.f32s()?;
            let n = r.read_usize()?;
            let evictions = r.read_usize()?;
            let log_len = r.read_usize()?;
            anyhow::ensure!(log_len <= EVICT_LOG_CAP, "h2o cache: log {log_len} over cap");
            let mut evict_log = std::collections::VecDeque::with_capacity(log_len);
            for _ in 0..log_len {
                let ordinal = r.read_usize()?;
                let idx = r.read_usize()?;
                evict_log.push_back((ordinal, idx));
            }
            anyhow::ensure!(
                k.cols == l.k.cols
                    && v.cols == l.v.cols
                    && k.rows() == abs_pos.len()
                    && v.rows() == abs_pos.len()
                    && score.len() == abs_pos.len()
                    && abs_pos.len() <= n,
                "h2o cache: inconsistent layer snapshot (kept={}, n={n})",
                abs_pos.len()
            );
            l.k = k;
            l.v = v;
            l.abs_pos = abs_pos;
            l.score = score;
            l.n = n;
            l.evictions = evictions;
            l.evict_log = evict_log;
        }
        r.expect_end()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn setup(budget: usize, t: usize, heavy: &[usize]) -> H2oCache {
        let d = 4;
        let mut rng = Pcg64::new(1);
        let mut c = H2oCache::new(1, d, budget);
        let x = Mat::randn(t, d, 1.0, &mut rng);
        let k = Mat::randn(t, d, 1.0, &mut rng);
        let v = Mat::randn(t, d, 1.0, &mut rng);
        c.ingest_prefill(0, &x, &k, &v);
        let mut mass = vec![0.1f32; t];
        for &h in heavy {
            mass[h] = 10.0;
        }
        c.observe_prefill_attn(0, &mass);
        c
    }

    #[test]
    fn heavy_hitters_survive_eviction() {
        let c = setup(8, 32, &[3, 7]);
        let view = c.materialize(0);
        assert_eq!(view.len(), 8);
        assert!(view.abs_pos.contains(&3), "heavy hitter 3 kept: {:?}", view.abs_pos);
        assert!(view.abs_pos.contains(&7), "heavy hitter 7 kept");
        // Recent half (last 4 positions) protected.
        for p in 28..32 {
            assert!(view.abs_pos.contains(&p), "recent {p} kept");
        }
        // Positions are absolute (not re-based).
        assert_eq!(view.rope_pos, view.abs_pos);
    }

    #[test]
    fn decode_scores_update_ranking() {
        let mut c = setup(8, 16, &[2]);
        // Pick a surviving non-heavy, non-recent position and attend to it
        // strongly during decode.
        let view = c.materialize(0);
        let boosted = view.abs_pos[1]; // survivor right after heavy-hitter 2
        assert_ne!(boosted, 2);
        let mut probs = vec![0.01f32; view.len()];
        probs[1] = 5.0;
        c.observe_decode_attn(0, &view.abs_pos, &probs);
        // Append enough tokens to force evictions.
        let mut rng = Pcg64::new(2);
        for _ in 0..6 {
            let row: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
            c.append(0, &row, &row, &row);
        }
        let view = c.materialize(0);
        assert_eq!(view.len(), 8);
        assert!(view.abs_pos.contains(&2), "prefill heavy hitter kept");
        assert!(
            view.abs_pos.contains(&boosted),
            "decode-boosted token {boosted} kept: {:?}",
            view.abs_pos
        );
    }

    #[test]
    fn budget_enforced_during_decode() {
        let mut c = setup(6, 12, &[]);
        let mut rng = Pcg64::new(3);
        for _ in 0..20 {
            let row: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
            c.append(0, &row, &row, &row);
            assert_eq!(c.len(0), 6);
        }
        // Newest token always kept (it's in the recent window).
        assert_eq!(*c.materialize(0).abs_pos.last().unwrap(), 31);
    }

    #[test]
    fn sync_view_incremental_matches_fresh_under_eviction() {
        let mut c = setup(8, 32, &[3, 7]);
        let mut live = DecodeView::new(4, 2, 10000.0);
        c.sync_view(0, &mut live);
        let mut rng = Pcg64::new(9);
        for _ in 0..12 {
            let row: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
            c.append(0, &row, &row, &row);
            c.sync_view(0, &mut live);
            live.validate();
            // Random decode-attention feedback moves the eviction target
            // around, exercising mid-list dirty ranges.
            let probs: Vec<f32> = (0..live.len()).map(|_| rng.normal().abs()).collect();
            let abs: Vec<usize> = live.abs_positions().to_vec();
            c.observe_decode_attn(0, &abs, &probs);
        }
        let mut fresh = DecodeView::new(4, 2, 10000.0);
        c.sync_view(0, &mut fresh);
        assert!(live.same_contents(&fresh));
        assert_eq!(live.len(), c.len(0));
    }

    #[test]
    fn total_seen_vs_kept() {
        let c = setup(4, 20, &[0]);
        assert_eq!(c.len(0), 4);
        assert_eq!(c.layers[0].n, 20);
    }
}
