//! `cskv` — leader binary: pretraining, compression, evaluation, serving.
//!
//! Subcommands:
//! * `info`      — show artifact manifest + model summary.
//! * `pretrain`  — train TinyLM through the PJRT `train_step` artifact.
//! * `compress`  — calibrate + ASVD-init + layer-wise fine-tune; saves factors.
//! * `eval`      — run one suite × policy grid cell.
//! * `serve`     — demo serving run through the coordinator.
//!
//! `serve` flags: `--requests N --n-new N --ctx N --max-batch N
//! --kv-budget-kb N --threads N --sequential` plus the control plane:
//! `--scheduler {fifo,size-aware,preemptive}` picks the admission/
//! preemption policy (fifo = strict arrival order; size-aware = shortest
//! work first within the KV budget; preemptive = size-aware + pager
//! swap-out under budget pressure). The pager's tier hierarchy is sized
//! by `--hot-kb N` (alias of `--kv-budget-kb`: the hot-tier KV budget),
//! `--warm-kb N` (byte budget for preempted block runs held encoded in
//! RAM), and `--disk-dir <dir>` (`--cold-tier` kept as an alias: where
//! over-budget blocks spill; all three pager flags require `--scheduler
//! preemptive`); `--pager-scoring {attention,age}` picks the eviction
//! priority and `--no-prefetch` disables overlapped restores (A/B
//! baselines for `bench_perf_paging`). `--prefix-cache-kb N` enables
//! the coordinator's radix prefix cache with an N-KiB byte budget
//! (admission then charges only each request's unshared suffix), and
//! `--request-timeout <secs>` gives every request a deadline — a
//! request still queued or decoding past it is answered `"deadline
//! exceeded"` (with its partial tokens, if any) and its KV/pager state
//! released at the next round boundary. Invalid combinations — a zero
//! prefix budget, a non-positive request timeout, an unwritable disk
//! dir, pager tiers without the preemptive scheduler, or zero
//! `--requests/--n-new/--ctx/--max-batch` — are rejected up front with
//! a clear error instead of failing mid-round.
//!
//! With `--listen <ip:port>` the demo loop is replaced by the HTTP/1.1
//! front-end ([`cskv::coordinator::http`]): `POST /generate` streams
//! tokens over SSE, `GET /healthz` / `/readyz` / `/stats` expose the
//! serving plane, and `POST /drain` (or `SIGTERM`) gracefully drains —
//! in-flight sequences are snapshotted to `--drain-file` and a second
//! process started with `--resume-from <bundle>` finishes them
//! bit-identically. Supporting flags: `--max-queued N` bounds
//! concurrent requests before 429-shedding (default 64, must be ≥ 1),
//! `--client-stall-timeout <secs>` cuts clients that stall a write that
//! long (default 10, must be positive), `--drain-grace <secs>` is the
//! finish window before snapshotting (default 5, must be ≥ 0),
//! `--seed-weights <seed>` serves freshly initialised `test_small`
//! weights (no artifacts needed — CI smoke path), and
//! `--decode-throttle-ms N` slows each decode step (deterministic
//! mid-stream windows for drain/disconnect testing). All are validated
//! up front like the rest of the `serve` flags.
//!
//! The benches (`cargo bench`) regenerate the paper's tables; this binary
//! is the operational entry point a user scripts against.

use std::sync::Arc;

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::coordinator::{Coordinator, CoordinatorConfig, RustSequenceBackend};
use cskv::data::corpus::{calibration_docs, CorpusConfig};
use cskv::data::{tasks, vocab};
use cskv::eval::{EvalSet, Suite};
use cskv::finetune::{build_factors, FinetuneConfig};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, QuantMode};
use cskv::model::{engine::Engine, ModelWeights};
use cskv::runtime::trainer::{TrainConfig, Trainer};
use cskv::runtime::Runtime;
use cskv::util::cli::Args;
use cskv::util::prng::Pcg64;
use cskv::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cmd = args.subcommand().unwrap_or("info").to_string();
    match cmd.as_str() {
        "info" => info(&args),
        "pretrain" => pretrain(&args),
        "compress" => compress(&args),
        "eval" => eval(&args),
        "serve" => serve(&args),
        other => {
            eprintln!("unknown subcommand {other:?}; try: info | pretrain | compress | eval | serve");
            std::process::exit(2);
        }
    }?;
    let unused = args.unused();
    if !unused.is_empty() {
        eprintln!("warning: unused flags {unused:?}");
    }
    Ok(())
}

fn info(_args: &Args) -> anyhow::Result<()> {
    let dir = cskv::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    match cskv::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "model: d_model={} layers={} heads={} vocab={} max_seq={} (~{} params)",
                m.model.d_model,
                m.model.n_layers,
                m.model.n_heads,
                m.model.vocab_size,
                m.model.max_seq,
                m.model.n_params()
            );
            let mut t = Table::new("executables", &["name", "file", "inputs", "outputs"]);
            for (name, e) in &m.executables {
                t.row(&[
                    name.clone(),
                    e.file.file_name().unwrap().to_string_lossy().to_string(),
                    e.inputs.len().to_string(),
                    e.outputs.len().to_string(),
                ]);
            }
            t.print();
        }
        Err(e) => println!("no artifacts ({e:#}); run `make artifacts`"),
    }
    let wpath = cskv::runs_dir().join("tinylm.bin");
    match ModelWeights::load(&wpath) {
        Ok(_) => println!("weights: {} (trained)", wpath.display()),
        Err(_) => println!("weights: {} missing — run `cskv pretrain`", wpath.display()),
    }
    Ok(())
}

fn pretrain(args: &Args) -> anyhow::Result<()> {
    let steps = args.get_usize("steps", 400);
    let seed = args.get_u64("seed", 1234);
    let lr = args.get_f64("lr", 3e-3) as f32;
    let out = args.get_str("out", cskv::runs_dir().join("tinylm.bin").to_str().unwrap());
    let rt = Runtime::load_default()?;
    let mut trainer = Trainer::new(&rt, seed)?;
    let losses = trainer.train(&TrainConfig {
        steps,
        lr,
        seed,
        log_every: args.get_usize("log-every", 20),
    })?;
    trainer.weights.save(std::path::Path::new(&out))?;
    // Persist the loss curve for EXPERIMENTS.md.
    let curve: String = losses
        .iter()
        .enumerate()
        .map(|(i, l)| format!("{i},{l}\n"))
        .collect();
    std::fs::write(cskv::runs_dir().join("pretrain_loss.csv"), format!("step,loss\n{curve}"))?;
    println!(
        "pretrained {steps} steps: loss {:.4} -> {:.4}; weights -> {out}",
        losses.first().copied().unwrap_or(f32::NAN),
        losses.last().copied().unwrap_or(f32::NAN)
    );
    // Quick self-check: retrieval accuracy with the full cache.
    let engine = Engine::new(Arc::new(ModelWeights::load(std::path::Path::new(&out))?));
    let set = EvalSet::build(&engine, Suite::LongEval { ctx: 128 }.sample_set(20, 7));
    let cfgm = engine.w.cfg.clone();
    let mut factory = move || -> Box<dyn cskv::kvcache::KvCachePolicy> {
        Box::new(FullCache::new(cfgm.n_layers, cfgm.d_model))
    };
    let r = set.eval(&engine, &mut factory);
    println!("sanity: LongEval-128 accuracy (full cache) = {:.2}", r.accuracy());
    Ok(())
}

fn load_engine(args: &Args) -> anyhow::Result<Engine> {
    // `--threads N` sizes the process-wide pool every parallel prefill
    // (eval harness, calibration, serving backends) draws from. Results
    // are bit-identical at any width — this is purely a speed knob.
    cskv::util::threadpool::set_global_threads(args.get_usize("threads", 1));
    let wpath = args.get_str(
        "weights",
        cskv::runs_dir().join("tinylm.bin").to_str().unwrap(),
    );
    let w = ModelWeights::load(std::path::Path::new(&wpath))
        .map_err(|e| anyhow::anyhow!("{e:#}\nhint: run `cskv pretrain` first"))?;
    Ok(Engine::new(Arc::new(w)))
}

fn compress(args: &Args) -> anyhow::Result<()> {
    let engine = load_engine(args)?;
    let ratio = args.get_f64("ratio", 0.8);
    let steps = args.get_usize("ft-steps", 200);
    let init = match args.get_str("init", "asvd").as_str() {
        "random" => InitMethod::Random,
        "svd" => InitMethod::Svd,
        "oracle" => InitMethod::Oracle,
        _ => InitMethod::asvd_default(),
    };
    let n_calib = args.get_usize("calib-docs", 32);
    let out = args.get_str(
        "out",
        cskv::runs_dir()
            .join(format!("factors_r{:02}.bin", (ratio * 100.0) as u32))
            .to_str()
            .unwrap(),
    );
    println!("collecting calibration activations ({n_calib} docs)...");
    let docs = calibration_docs(&CorpusConfig::default(), n_calib, 99);
    let calib = engine.collect_calibration(&docs, 4096, 1);
    let plan = KvCompressionPlan::uniform(ratio);
    println!(
        "fine-tuning factors: ratio {ratio} (rank {}/{}), init {}, {steps} steps/layer",
        plan.rank_k(engine.w.cfg.d_model),
        plan.rank_v(engine.w.cfg.d_model),
        init.name()
    );
    let rep = build_factors(
        &engine.w,
        &calib,
        plan,
        &FinetuneConfig {
            init,
            steps,
            seed: args.get_u64("seed", 0),
            ..Default::default()
        },
    );
    println!("final total reconstruction loss (Eq.2): {:.6}", rep.final_total_loss);
    rep.factors.save(std::path::Path::new(&out))?;
    println!("factors -> {out} ({})", rep.factors.provenance);
    Ok(())
}

fn eval(args: &Args) -> anyhow::Result<()> {
    let engine = load_engine(args)?;
    let cfg = engine.w.cfg.clone();
    let ctx = args.get_usize("ctx", 128);
    let n = args.get_usize("samples", 25);
    let seed = args.get_u64("seed", 42);
    let suite = match args.get_str("suite", "longeval").as_str() {
        "longbench" => Suite::LongBench { ctx, n_facts: 6 },
        "lveval" => Suite::LvEval { ctx },
        _ => Suite::LongEval { ctx },
    };
    let set = EvalSet::build(&engine, suite.sample_set(n, seed));

    let policy = args.get_str("policy", "full");
    let mut factory: Box<dyn FnMut() -> Box<dyn cskv::kvcache::KvCachePolicy>> = match policy.as_str() {
        "full" => {
            let c = cfg.clone();
            Box::new(move || Box::new(FullCache::new(c.n_layers, c.d_model)))
        }
        "cskv" => {
            let fpath = args.get_str(
                "factors",
                cskv::runs_dir().join("factors_r80.bin").to_str().unwrap(),
            );
            let f = Arc::new(cskv::compress::ModelFactors::load(std::path::Path::new(&fpath))?);
            let window = args.get_usize("window", 32);
            let c = cfg.clone();
            Box::new(move || {
                Box::new(CskvCache::new(
                    Arc::clone(&f),
                    c.d_model,
                    CskvConfig {
                        window,
                        quant: QuantMode::None,
                    },
                ))
            })
        }
        other => anyhow::bail!("unknown --policy {other:?} (full|cskv)"),
    };
    let r = set.eval(&engine, &mut factory);
    println!(
        "{} ctx={ctx} n={n}: policy={} accuracy={:.2} mean_kv={}",
        args.get_str("suite", "longeval"),
        r.policy,
        r.accuracy(),
        cskv::util::table::bytes(r.mean_kv_bytes as usize)
    );
    Ok(())
}

/// Satellite of the prefix-cache PR: every `serve` flag combination
/// that used to surface as a confusing mid-round failure (or a silent
/// degrade) is rejected here, before any model work starts.
fn validate_serve_flags(args: &Args, coord_cfg: &CoordinatorConfig) -> anyhow::Result<()> {
    for knob in ["requests", "n-new", "ctx", "max-batch"] {
        if let Some(v) = args.get_opt(knob) {
            anyhow::ensure!(
                v.parse::<usize>().map(|n| n > 0).unwrap_or(false),
                "--{knob} must be a positive integer, got {v:?}"
            );
        }
    }
    if let Some(v) = args.get_opt("prefix-cache-kb") {
        anyhow::ensure!(
            v.parse::<usize>().map(|n| n > 0).unwrap_or(false),
            "--prefix-cache-kb must be a positive KiB budget, got {v:?} \
             (omit the flag to disable the prefix cache)"
        );
    }
    if let Some(v) = args.get_opt("request-timeout") {
        anyhow::ensure!(
            v.parse::<f64>().map(|s| s > 0.0 && s.is_finite()).unwrap_or(false),
            "--request-timeout must be a positive number of seconds, got {v:?} \
             (omit the flag to let requests wait indefinitely)"
        );
    }
    if let Some(dir) = &coord_cfg.disk_dir {
        anyhow::ensure!(
            matches!(coord_cfg.scheduler, cskv::coordinator::SchedulerKind::Preemptive),
            "--disk-dir only takes effect with --scheduler preemptive \
             (got {}); drop the flag or switch scheduler",
            coord_cfg.scheduler.name()
        );
        cskv::coordinator::Pager::probe_dir(dir)
            .map_err(|e| anyhow::anyhow!("--disk-dir unusable: {e}"))?;
    }
    if let Some(v) = args.get_opt("hot-kb") {
        anyhow::ensure!(
            v.parse::<usize>().is_ok(),
            "--hot-kb must be a non-negative KiB budget, got {v:?} \
             (0 disables the hot-tier budget, like --kv-budget-kb)"
        );
    }
    if let Some(v) = args.get_opt("warm-kb") {
        anyhow::ensure!(
            matches!(coord_cfg.scheduler, cskv::coordinator::SchedulerKind::Preemptive),
            "--warm-kb only takes effect with --scheduler preemptive \
             (got {}); drop the flag or switch scheduler",
            coord_cfg.scheduler.name()
        );
        anyhow::ensure!(
            v.parse::<usize>().is_ok(),
            "--warm-kb must be a non-negative KiB budget, got {v:?} \
             (0 spills every preempted block to --disk-dir)"
        );
    }
    if let Some(v) = args.get_opt("pager-scoring") {
        cskv::coordinator::EvictionScoring::parse(&v)?;
    }
    // HTTP front-end flags (only meaningful with --listen, but validated
    // whenever supplied so a typo'd invocation fails loudly either way).
    if let Some(v) = args.get_opt("listen") {
        cskv::coordinator::parse_listen(&v)?;
    }
    if let Some(v) = args.get_opt("max-queued") {
        anyhow::ensure!(
            v.parse::<usize>().map(|n| n > 0).unwrap_or(false),
            "--max-queued must be a positive integer, got {v:?} \
             (the admission gate needs room for at least one request)"
        );
    }
    if let Some(v) = args.get_opt("client-stall-timeout") {
        anyhow::ensure!(
            v.parse::<f64>().map(|s| s > 0.0 && s.is_finite()).unwrap_or(false),
            "--client-stall-timeout must be a positive number of seconds, got {v:?}"
        );
    }
    if let Some(v) = args.get_opt("drain-grace") {
        anyhow::ensure!(
            v.parse::<f64>().map(|s| s >= 0.0 && s.is_finite()).unwrap_or(false),
            "--drain-grace must be a non-negative number of seconds, got {v:?} \
             (0 snapshots in-flight sequences immediately)"
        );
    }
    if let Some(v) = args.get_opt("seed-weights") {
        anyhow::ensure!(
            v.parse::<u64>().is_ok(),
            "--seed-weights must be an integer seed, got {v:?}"
        );
    }
    if let Some(v) = args.get_opt("decode-throttle-ms") {
        anyhow::ensure!(
            v.parse::<usize>().is_ok(),
            "--decode-throttle-ms must be a non-negative integer, got {v:?}"
        );
    }
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let n_req = args.get_usize("requests", 16);
    let n_new = args.get_usize("n-new", vocab::VALUE_LEN);
    // --hot-kb is the pager-era spelling of the hot-tier KV budget;
    // --kv-budget-kb stays as the original alias.
    let budget_kb = match args.get_opt("hot-kb") {
        Some(v) => v.parse::<usize>().unwrap_or(0),
        None => args.get_usize("kv-budget-kb", 0),
    };
    let coord_cfg = CoordinatorConfig {
        max_batch: args.get_usize("max-batch", 4),
        kv_budget_bytes: if budget_kb == 0 { None } else { Some(budget_kb * 1024) },
        // One pool width for every sequence backend in the process.
        threads: args.get_usize("threads", 0),
        // --sequential restores per-sequence prefill/decode rounds
        // (identical token streams; fused is the fast path).
        fused: !args.get_flag("sequential"),
        // --scheduler fifo|size-aware|preemptive: the control plane.
        scheduler: cskv::coordinator::SchedulerKind::parse(
            &args.get_str("scheduler", "fifo"),
        )?,
        // --disk-dir <dir> (--cold-tier kept as an alias): spill
        // over-budget pager blocks to disk.
        disk_dir: args
            .get_opt("disk-dir")
            .or_else(|| args.get_opt("cold-tier"))
            .map(std::path::PathBuf::from),
        // --warm-kb N: RAM budget for preempted block runs (encoded).
        warm_budget_bytes: args
            .get_opt("warm-kb")
            .and_then(|v| v.parse::<usize>().ok().map(|kb| kb * 1024)),
        // --pager-scoring attention|age: spill-priority policy.
        pager_scoring: args
            .get_opt("pager-scoring")
            .map(|v| {
                cskv::coordinator::EvictionScoring::parse(&v)
                    .expect("checked by validate_serve_flags")
            })
            .unwrap_or_default(),
        // --no-prefetch: disable overlapped restores (A/B baseline).
        pager_prefetch: !args.get_flag("no-prefetch"),
        // --prefix-cache-kb N: shared-prefix KV reuse across requests.
        prefix_cache_bytes: args.get_opt("prefix-cache-kb").and_then(|v| {
            v.parse::<usize>().ok().map(|kb| kb * 1024)
        }),
        // --request-timeout <secs>: default deadline for every request.
        // (The filter keeps from_secs_f64 panic-safe; bad values are
        // rejected with a message by validate_serve_flags below.)
        request_timeout: args.get_opt("request-timeout").and_then(|v| {
            v.parse::<f64>()
                .ok()
                .filter(|s| *s > 0.0 && s.is_finite())
                .map(std::time::Duration::from_secs_f64)
        }),
        faults: cskv::util::faults::FaultInjector::none(),
    };
    validate_serve_flags(args, &coord_cfg)?;
    let engine = match args.get_opt("seed-weights") {
        // Freshly initialised weights: the HTTP smoke path needs no
        // pretrain artifacts and stays bit-reproducible across processes.
        Some(v) => {
            cskv::util::threadpool::set_global_threads(args.get_usize("threads", 1));
            let seed: u64 = v.parse().expect("checked by validate_serve_flags");
            let cfg = cskv::model::ModelConfig::test_small();
            Engine::new(Arc::new(ModelWeights::init(&cfg, seed)))
        }
        None => load_engine(args)?,
    };
    let cfg = engine.w.cfg.clone();
    let sched = coord_cfg.scheduler;
    let throttle = args.get_usize("decode-throttle-ms", 0);
    let eng = engine.clone();
    let coord = Coordinator::start(
        Box::new(move || {
            let engine = eng;
            let factory: cskv::coordinator::server::BackendFactory = Box::new(move || {
                let c = engine.w.cfg.clone();
                let inner: Box<dyn cskv::coordinator::SequenceBackend> =
                    Box::new(RustSequenceBackend::new(
                        engine.clone(),
                        Box::new(FullCache::new(c.n_layers, c.d_model)),
                    ));
                Ok(if throttle == 0 {
                    inner
                } else {
                    Box::new(cskv::coordinator::ThrottledBackend::new(
                        inner,
                        std::time::Duration::from_millis(throttle as u64),
                    ))
                })
            });
            Ok(factory)
        }),
        coord_cfg,
    );
    if let Some(listen) = args.get_opt("listen") {
        return serve_http(args, coord, &cfg, &listen);
    }
    let mut rng = Pcg64::new(7);
    let mut correct = 0usize;
    let mut rxs = Vec::new();
    let mut answers = Vec::new();
    for _ in 0..n_req {
        let s = tasks::line_retrieval_ctx(args.get_usize("ctx", 128), &mut rng);
        answers.push(s.answer.clone());
        rxs.push(coord.submit(s.prompt, n_new));
    }
    for (rx, ans) in rxs.into_iter().zip(answers) {
        let resp = rx.recv()?;
        if tasks::score_exact(&resp.tokens, &ans) {
            correct += 1;
        }
    }
    let snap = coord.shutdown();
    println!(
        "served {n_req} requests (ctx up to {}, scheduler {}):",
        cfg.max_seq,
        sched.name()
    );
    println!("  {}", snap.report());
    if let Some(rate) = snap.prefix_hit_rate() {
        println!(
            "  prefix cache: {:.0}% hit rate, {} shared, {} evictions, {} resident peak",
            rate * 100.0,
            cskv::util::table::bytes(snap.prefix_shared_bytes as usize),
            snap.prefix_evictions,
            cskv::util::table::bytes(snap.prefix_bytes_peak),
        );
    }
    if let Some(tiers) = snap.pager_tiers() {
        println!("  pager: {tiers}");
    }
    if let Some(health) = snap.pager_health() {
        println!("  pager health: {health}");
    }
    println!("  retrieval accuracy: {:.2}", correct as f64 / n_req as f64);
    snap.summary_table().print();
    Ok(())
}

/// The `--listen` serving path: bind, optionally resume another
/// process's drain bundle, then run the HTTP front-end until a drain
/// (`POST /drain` or `SIGTERM`) stops it.
fn serve_http(
    args: &Args,
    coord: Coordinator,
    cfg: &cskv::model::ModelConfig,
    listen: &str,
) -> anyhow::Result<()> {
    use cskv::util::json::Json;
    let addr = cskv::coordinator::parse_listen(listen)?;
    let http_cfg = cskv::coordinator::HttpConfig {
        max_queued: args.get_usize("max-queued", 64),
        client_stall_timeout: std::time::Duration::from_secs_f64(
            args.get_f64("client-stall-timeout", 10.0),
        ),
        drain_grace: std::time::Duration::from_secs_f64(args.get_f64("drain-grace", 5.0)),
        drain_file: args.get_opt("drain-file").map(std::path::PathBuf::from),
        vocab_size: cfg.vocab_size,
        max_seq: cfg.max_seq,
        ..Default::default()
    };
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("cannot bind {addr}: {e}"))?;
    // The test harness (and any supervisor binding port 0) parses this
    // line for the resolved address.
    println!("listening on {}", listener.local_addr()?);
    if let Some(p) = args.get_opt("resume-from") {
        let bundle = cskv::coordinator::DrainBundle::load(std::path::Path::new(&p))
            .map_err(|e| anyhow::anyhow!("--resume-from {p}: {e:#}"))?;
        println!("resuming {} migrated sequence(s) from {p}", bundle.seqs.len());
        for (id, tokens, error) in cskv::coordinator::resume_bundle(&coord, bundle) {
            match error {
                None => {
                    let toks = Json::Arr(tokens.into_iter().map(Json::from).collect());
                    println!("resumed id={id} tokens={}", toks.to_string_compact());
                }
                Some(e) => println!("resume id={id} failed: {e}"),
            }
        }
    }
    let snap = cskv::coordinator::serve(coord, listener, http_cfg)?;
    println!("drained; final stats:");
    println!("  {}", snap.report());
    Ok(())
}
