//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json` (the interchange
//!   contract written by `python/compile/aot.py`).
//! * [`client`] — PJRT CPU client wrapper: HLO text → compile → execute,
//!   with host-value marshalling and shape checking against the manifest.
//!
//! Pattern follows `/opt/xla-example/load_hlo`: HLO **text** is the
//! interchange format (xla_extension 0.5.1 rejects jax≥0.5 serialized
//! protos), lowered with `return_tuple=True` and unpacked with
//! `Literal::to_tuple`.

pub mod client;
pub mod manifest;
pub mod trainer;

pub use client::{Runtime, Value};
pub use manifest::{Dtype, ExecutableSpec, Manifest, TensorSpec};
