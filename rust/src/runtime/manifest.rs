//! Typed view of the AOT manifest (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::model::ModelConfig;
use crate::util::json::Json;

/// Element type of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => anyhow::bail!("unsupported dtype {other:?}"),
        }
    }
}

/// One input/output tensor declaration.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> anyhow::Result<Self> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("tensor spec missing name"))?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("tensor {name}: missing shape"))?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let dtype = Dtype::parse(j.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One AOT executable: HLO file + ordered I/O contract.
#[derive(Clone, Debug)]
pub struct ExecutableSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub static_params: BTreeMap<String, f64>,
}

impl ExecutableSpec {
    pub fn static_usize(&self, key: &str) -> Option<usize> {
        self.static_params.get(key).map(|v| *v as usize)
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelConfig,
    pub executables: BTreeMap<String, ExecutableSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        anyhow::ensure!(
            j.get("format").and_then(Json::as_str) == Some("hlo-text-v1"),
            "unknown manifest format"
        );
        let model = ModelConfig::from_json(
            j.get("model").ok_or_else(|| anyhow::anyhow!("manifest missing model"))?,
        )?;
        let mut executables = BTreeMap::new();
        let exes = j
            .get("executables")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing executables"))?;
        for (name, e) in exes {
            let file = dir.join(
                e.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("{name}: missing file"))?,
            );
            anyhow::ensure!(file.exists(), "{name}: artifact {} missing", file.display());
            let parse_list = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let mut static_params = BTreeMap::new();
            if let Some(s) = e.get("static").and_then(Json::as_obj) {
                for (k, v) in s {
                    if let Some(n) = v.as_f64() {
                        static_params.insert(k.clone(), n);
                    }
                }
            }
            executables.insert(
                name.clone(),
                ExecutableSpec {
                    name: name.clone(),
                    file,
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                    static_params,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            executables,
        })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ExecutableSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("executable {name:?} not in manifest"))
    }

    /// Names of exported CSKV decode variants with their ranks.
    pub fn cskv_ranks(&self) -> Vec<(String, usize)> {
        self.executables
            .iter()
            .filter(|(n, _)| n.starts_with("decode_cskv"))
            .filter_map(|(n, e)| e.static_usize("rank").map(|r| (n.clone(), r)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule fake").unwrap();
        let cfg = ModelConfig::tiny().to_json().to_string_compact();
        let man = format!(
            r#"{{"format":"hlo-text-v1","model":{cfg},"executables":{{
                "x":{{"file":"x.hlo.txt",
                      "inputs":[{{"name":"a","shape":[2,3],"dtype":"f32"}},
                                 {{"name":"n","shape":[],"dtype":"i32"}}],
                      "outputs":[{{"name":"o","shape":[2],"dtype":"f32"}}],
                      "static":{{"rank":26}}}}}}}}"#
        );
        std::fs::write(dir.join("manifest.json"), man).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("cskv_test_manifest");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.d_model, 128);
        let e = m.get("x").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].elements(), 6);
        assert_eq!(e.inputs[1].dtype, Dtype::I32);
        assert_eq!(e.inputs[1].elements(), 1);
        assert_eq!(e.static_usize("rank"), Some(26));
        assert_eq!(e.input_index("n"), Some(1));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn missing_artifact_file_rejected() {
        let dir = std::env::temp_dir().join("cskv_test_manifest2");
        write_fake_manifest(&dir);
        std::fs::remove_file(dir.join("x.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
