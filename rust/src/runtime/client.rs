//! PJRT client wrapper: compile + execute AOT artifacts with host values.
//!
//! Executables are compiled lazily on first use and cached; host values are
//! shape-checked against the manifest before every call so contract drift
//! between `aot.py` and the Rust side fails loudly rather than numerically.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;

use crate::tensor::Mat;

use super::manifest::{Dtype, Manifest, TensorSpec};

/// A host-side tensor value (what crosses the PJRT boundary).
#[derive(Clone, Debug)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32 {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn scalar_i32(x: i32) -> Value {
        Value::I32 {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn from_mat(m: &Mat) -> Value {
        Value::F32 {
            shape: vec![m.rows, m.cols],
            data: m.data.clone(),
        }
    }

    /// Stack matrices into `[n, rows, cols]`.
    pub fn from_mats(ms: &[&Mat]) -> Value {
        assert!(!ms.is_empty());
        let (r, c) = (ms[0].rows, ms[0].cols);
        let mut data = Vec::with_capacity(ms.len() * r * c);
        for m in ms {
            assert_eq!((m.rows, m.cols), (r, c), "ragged stack");
            data.extend_from_slice(&m.data);
        }
        Value::F32 {
            shape: vec![ms.len(), r, c],
            data,
        }
    }

    pub fn f32_vec(shape: Vec<usize>, data: Vec<f32>) -> Value {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Value::F32 { shape, data }
    }

    pub fn i32_vec(shape: Vec<usize>, data: Vec<i32>) -> Value {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Value::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } | Value::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32 { .. } => Dtype::F32,
            Value::I32 { .. } => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => anyhow::bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            _ => anyhow::bail!("expected i32 value"),
        }
    }

    /// Interpret as a 2-D matrix (higher ranks must pass explicit dims).
    pub fn to_mat(&self) -> anyhow::Result<Mat> {
        let data = self.as_f32()?.to_vec();
        let shape = self.shape();
        match shape.len() {
            2 => Ok(Mat::from_vec(shape[0], shape[1], data)),
            1 => Ok(Mat::from_vec(1, shape[0], data)),
            _ => anyhow::bail!("to_mat on rank-{} value", shape.len()),
        }
    }

    /// Slice index `i` of the leading axis of a rank-3 value as a matrix.
    pub fn mat_at(&self, i: usize) -> anyhow::Result<Mat> {
        let shape = self.shape();
        anyhow::ensure!(shape.len() == 3, "mat_at needs rank-3, got {shape:?}");
        let (n, r, c) = (shape[0], shape[1], shape[2]);
        anyhow::ensure!(i < n, "index {i} out of {n}");
        let data = self.as_f32()?[i * r * c..(i + 1) * r * c].to_vec();
        Ok(Mat::from_vec(r, c, data))
    }

    fn check(&self, spec: &TensorSpec) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.dtype() == spec.dtype,
            "{}: dtype mismatch (got {:?}, want {:?})",
            spec.name,
            self.dtype(),
            spec.dtype
        );
        anyhow::ensure!(
            self.shape() == &spec.shape[..],
            "{}: shape mismatch (got {:?}, want {:?})",
            spec.name,
            self.shape(),
            spec.shape
        );
        Ok(())
    }

    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32 { data, .. } => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            }
            Value::I32 { data, .. } => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> anyhow::Result<Value> {
        let v = match spec.dtype {
            Dtype::F32 => Value::F32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<f32>()?,
            },
            Dtype::I32 => Value::I32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<i32>()?,
            },
        };
        let n = match &v {
            Value::F32 { data, .. } => data.len(),
            Value::I32 { data, .. } => data.len(),
        };
        anyhow::ensure!(
            n == spec.elements(),
            "{}: runtime returned {n} elements, manifest says {}",
            spec.name,
            spec.elements()
        );
        Ok(v)
    }
}

/// Lazily-compiling executor over the AOT manifest.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        crate::log_info!(
            "runtime up: platform={} artifacts={} executables={}",
            client.platform_name(),
            dir.display(),
            manifest.executables.len()
        );
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(BTreeMap::new()),
        })
    }

    /// Convenience: load from the default artifacts dir.
    pub fn load_default() -> anyhow::Result<Self> {
        Self::load(&crate::artifacts_dir())
    }

    fn ensure_compiled(&self, name: &str) -> anyhow::Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        crate::log_info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with ordered inputs, returning ordered outputs.
    pub fn execute(&self, name: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        let spec = self.manifest.get(name)?.clone();
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "{name}: got {} inputs, want {}",
            inputs.len(),
            spec.inputs.len()
        );
        for (v, s) in inputs.iter().zip(&spec.inputs) {
            v.check(s)?;
        }
        self.ensure_compiled(name)?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<anyhow::Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "{name}: got {} outputs, want {}",
            parts.len(),
            spec.outputs.len()
        );
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| Value::from_literal(lit, s))
            .collect()
    }

    /// Pre-compile a set of executables (the serving path does this at
    /// startup so first-request latency is clean).
    pub fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_roundtrip_shapes() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = Value::from_mat(&m);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.to_mat().unwrap(), m);
        let stacked = Value::from_mats(&[&m, &m]);
        assert_eq!(stacked.shape(), &[2, 2, 3]);
        assert_eq!(stacked.mat_at(1).unwrap(), m);
    }

    #[test]
    fn value_check_catches_mismatch() {
        let spec = TensorSpec {
            name: "t".into(),
            shape: vec![2, 2],
            dtype: Dtype::F32,
        };
        assert!(Value::f32_vec(vec![2, 2], vec![0.0; 4]).check(&spec).is_ok());
        assert!(Value::f32_vec(vec![4], vec![0.0; 4]).check(&spec).is_err());
        assert!(Value::i32_vec(vec![2, 2], vec![0; 4]).check(&spec).is_err());
    }

    #[test]
    fn scalars() {
        let s = Value::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.as_i32().unwrap(), &[7]);
        assert!(s.as_f32().is_err());
    }
}
