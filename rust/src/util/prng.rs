//! Deterministic pseudo-random number generation.
//!
//! `rand` is unavailable offline, so this module provides a small,
//! reproducible RNG stack used across data generation, weight init,
//! property tests and benchmarks:
//!
//! * [`SplitMix64`] — streaming seeder (also a decent standalone generator).
//! * [`Pcg64`] — xoshiro256** main generator (fast, 2^256-1 period).
//!
//! All experiment entry points take explicit seeds so every table in
//! EXPERIMENTS.md is bit-reproducible.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
///
/// Named `Pcg64` for brevity at call sites; the algorithm is Blackman &
/// Vigna's xoshiro256** 1.0.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    s: [u64; 4],
}

impl Pcg64 {
    /// Create a generator from a seed. Distinct seeds give statistically
    /// independent streams (expanded through SplitMix64 per the xoshiro
    /// authors' recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-layer / per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// method to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — generation is not on any hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Normal with explicit mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly pick an element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fill a slice with samples ~ N(0, std).
    pub fn fill_normal(&mut self, xs: &mut [f32], std: f32) {
        for x in xs.iter_mut() {
            *x = self.normal() * std;
        }
    }

    /// Fill a slice with samples ~ U(-a, a).
    pub fn fill_uniform(&mut self, xs: &mut [f32], a: f32) {
        for x in xs.iter_mut() {
            *x = self.uniform_in(-a, a);
        }
    }

    /// Sample from a categorical distribution given unnormalized
    /// non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|w| *w as f64).sum();
        debug_assert!(total > 0.0);
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= *w as f64;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_give_distinct_streams() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            let expect = n as f64 / 5.0;
            assert!((c as f64 - expect).abs() < expect * 0.05, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(13);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Pcg64::new(1234);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
