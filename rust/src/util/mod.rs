//! Offline substrates.
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (tokio, clap, serde, criterion, proptest,
//! rayon, rand) are unavailable. Everything the system needs from them is
//! implemented here from scratch:
//!
//! * [`prng`] — SplitMix64 / Xoshiro256** deterministic RNG.
//! * [`faults`] — seeded fault-injection registry (chaos testing).
//! * [`json`] — minimal JSON parser + writer (artifact manifests, results).
//! * [`cli`] — declarative command-line argument parser.
//! * [`log`] — leveled logger controlled by `CSKV_LOG`.
//! * [`threadpool`] — scoped worker pool + `parallel_for`.
//! * [`stats`] — streaming mean/variance, percentiles, histograms.
//! * [`bench`] — micro/macro benchmark harness (criterion stand-in).
//! * [`prop`] — property-based testing microframework (proptest stand-in).
//! * [`table`] — aligned ASCII table printer for paper-style outputs.

pub mod bench;
pub mod cli;
pub mod faults;
pub mod json;
pub mod log;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
pub mod threadpool;
