//! Tiny leveled logger (the `log`/`env_logger` stack is not wired here to
//! keep the dependency surface at zero).
//!
//! Level is read once from `CSKV_LOG` (`error|warn|info|debug|trace`,
//! default `info`). Output goes to stderr with a monotonic timestamp so
//! interleaved coordinator logs can be ordered.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let parsed = match std::env::var("CSKV_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (tests, quiet benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let dt = t0.elapsed().as_secs_f64();
    eprintln!("[{dt:9.3}s {} {module}] {msg}", l.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
