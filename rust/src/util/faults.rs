//! Deterministic fault injection for the serving control plane.
//!
//! Production I/O faults (a full disk, a flaky mount, a corrupted blob)
//! are rare and unreproducible; this module makes them *scheduled*. A
//! [`FaultInjector`] is a registry of **named fault points** — strings
//! like `"pager.write"` — that production code consults on its error
//! paths. Each armed point carries a [`FaultMode`] deciding which hits
//! fire (fail-the-Nth, fail-from-the-Nth, fail-with-probability) and a
//! PRNG forked deterministically from the injector's seed and the point
//! name, so a given `(seed, arm calls)` pair replays the exact same
//! fault schedule on every run — the property `rust/tests/
//! chaos_serving.rs` builds its oracles on.
//!
//! The default injector is **inert**: no allocation, no locking beyond a
//! single `Option` check, and every fault point reports "don't fail".
//! Production builds pay one branch per consulted error path.
//!
//! Registered points in the coordinator:
//!
//! | point | consulted by | effect when fired |
//! |-------|--------------|-------------------|
//! | `pager.write` | each block spill-write attempt | that attempt errors |
//! | `pager.read`  | each block read attempt (sync restore *and* background prefetch) | that attempt errors |
//! | `snapshot.corrupt` | pager restore, pre-decode | one seeded byte of the re-merged blob is flipped |
//! | `backend.build` | worker backend construction | the build errors |
//! | `http.accept` | the HTTP accept loop, per connection | the connection is dropped before any byte is read (client sees a reset) |
//! | `http.write` | each SSE data frame (pings exempt) | the frame is truncated mid-write ("short write"), surfacing `BrokenPipe` → the request is cancelled |

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::prng::{Pcg64, SplitMix64};

/// Which hits of a fault point fire. Hit counts are 1-based.
#[derive(Clone, Copy, Debug)]
pub enum FaultMode {
    /// Fire on exactly the `n`th hit (transient fault: retry succeeds).
    Nth(u64),
    /// Fire on the `n`th hit and every later one (persistent fault:
    /// retries are exhausted). `FromNth(1)` fails always.
    FromNth(u64),
    /// Fire each hit independently with probability `p`, drawn from the
    /// point's seeded PRNG stream.
    Probability(f64),
}

struct Point {
    mode: FaultMode,
    hits: u64,
    trips: u64,
    rng: Pcg64,
}

impl Point {
    /// Count a hit; true when the armed mode says this one fires.
    fn fire(&mut self) -> bool {
        self.hits += 1;
        let fired = match self.mode {
            FaultMode::Nth(n) => self.hits == n,
            FaultMode::FromNth(n) => self.hits >= n,
            FaultMode::Probability(p) => self.rng.chance(p),
        };
        if fired {
            self.trips += 1;
        }
        fired
    }
}

#[derive(Default)]
struct Registry {
    seed: u64,
    points: HashMap<String, Point>,
}

/// Handle to a shared fault registry. Cloning shares the registry (the
/// test arms points on its clone; the worker thread consults its own),
/// and the inert default makes the production path a no-op.
#[derive(Clone, Default)]
pub struct FaultInjector {
    inner: Option<Arc<Mutex<Registry>>>,
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            Some(_) => write!(f, "FaultInjector(armed)"),
            None => write!(f, "FaultInjector(inert)"),
        }
    }
}

/// FNV-1a over the point name: mixes the name into the per-point PRNG
/// seed so two points armed in any order get independent streams.
fn name_hash(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl FaultInjector {
    /// The inert injector: every point reports "don't fail".
    pub fn none() -> Self {
        FaultInjector::default()
    }

    /// An active (but initially empty) registry. `seed` drives every
    /// probabilistic trigger and every corruption site deterministically.
    pub fn seeded(seed: u64) -> Self {
        FaultInjector {
            inner: Some(Arc::new(Mutex::new(Registry {
                seed,
                points: HashMap::new(),
            }))),
        }
    }

    pub fn is_armed(&self) -> bool {
        self.inner.is_some()
    }

    /// Arm `point` with `mode` (re-arming resets its hit/trip counters).
    /// No-op on the inert injector.
    pub fn arm(&self, point: &str, mode: FaultMode) {
        if let Some(inner) = &self.inner {
            let mut g = inner.lock().unwrap();
            let rng = Pcg64::new(SplitMix64::new(g.seed ^ name_hash(point)).next_u64());
            g.points.insert(
                point.to_string(),
                Point { mode, hits: 0, trips: 0, rng },
            );
        }
    }

    /// Count a hit at `point`; `Err` when an armed fault fires. Inert or
    /// unarmed points always return `Ok`.
    pub fn trip(&self, point: &str) -> anyhow::Result<()> {
        if let Some(inner) = &self.inner {
            let mut g = inner.lock().unwrap();
            if let Some(p) = g.points.get_mut(point) {
                if p.fire() {
                    let hit = p.hits;
                    anyhow::bail!("injected fault at {point} (hit {hit})");
                }
            }
        }
        Ok(())
    }

    /// Count a hit at `point`; when the armed fault fires, XOR one
    /// seeded byte of `bytes` with a seeded non-zero mask. Returns
    /// whether a corruption was injected.
    pub fn corrupt(&self, point: &str, bytes: &mut [u8]) -> bool {
        if bytes.is_empty() {
            return false;
        }
        if let Some(inner) = &self.inner {
            let mut g = inner.lock().unwrap();
            if let Some(p) = g.points.get_mut(point) {
                if p.fire() {
                    let off = p.rng.below(bytes.len());
                    let mask = (p.rng.range(1, 256)) as u8;
                    bytes[off] ^= mask;
                    return true;
                }
            }
        }
        false
    }

    /// Times `point` was consulted (hit), regardless of firing.
    pub fn hits(&self, point: &str) -> u64 {
        self.counter(point, |p| p.hits)
    }

    /// Times `point` actually fired — the test-side observability hook
    /// ("every injected fault yields exactly one error `Response`").
    pub fn trips(&self, point: &str) -> u64 {
        self.counter(point, |p| p.trips)
    }

    fn counter(&self, point: &str, get: impl Fn(&Point) -> u64) -> u64 {
        match &self.inner {
            Some(inner) => inner
                .lock()
                .unwrap()
                .points
                .get(point)
                .map(&get)
                .unwrap_or(0),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_injector_never_fails() {
        let f = FaultInjector::none();
        assert!(!f.is_armed());
        f.arm("x", FaultMode::FromNth(1)); // silently ignored
        for _ in 0..10 {
            assert!(f.trip("x").is_ok());
        }
        let mut b = [1u8, 2, 3];
        assert!(!f.corrupt("x", &mut b));
        assert_eq!(b, [1, 2, 3]);
        assert_eq!(f.trips("x"), 0);
    }

    #[test]
    fn nth_fires_exactly_once() {
        let f = FaultInjector::seeded(1);
        f.arm("p", FaultMode::Nth(3));
        let fired: Vec<bool> = (0..6).map(|_| f.trip("p").is_err()).collect();
        assert_eq!(fired, [false, false, true, false, false, false]);
        assert_eq!(f.hits("p"), 6);
        assert_eq!(f.trips("p"), 1);
        // Unarmed points on an armed injector pass through.
        assert!(f.trip("other").is_ok());
    }

    #[test]
    fn from_nth_is_persistent() {
        let f = FaultInjector::seeded(2);
        f.arm("p", FaultMode::FromNth(2));
        let fired: Vec<bool> = (0..4).map(|_| f.trip("p").is_err()).collect();
        assert_eq!(fired, [false, true, true, true]);
        assert_eq!(f.trips("p"), 3);
    }

    #[test]
    fn probability_is_seed_deterministic() {
        let schedule = |seed: u64| -> Vec<bool> {
            let f = FaultInjector::seeded(seed);
            f.arm("p", FaultMode::Probability(0.5));
            (0..64).map(|_| f.trip("p").is_err()).collect()
        };
        assert_eq!(schedule(7), schedule(7), "same seed, same schedule");
        assert_ne!(schedule(7), schedule(8), "distinct seeds diverge");
        let n = schedule(7).iter().filter(|&&b| b).count();
        assert!((16..=48).contains(&n), "p=0.5 over 64 hits, got {n}");
    }

    #[test]
    fn corruption_flips_exactly_one_byte_deterministically() {
        let run = |seed: u64| -> Vec<u8> {
            let f = FaultInjector::seeded(seed);
            f.arm("c", FaultMode::Nth(1));
            let mut b = vec![0u8; 32];
            assert!(f.corrupt("c", &mut b));
            b
        };
        let (a, b) = (run(5), run(5));
        assert_eq!(a, b, "same seed corrupts the same byte");
        assert_eq!(a.iter().filter(|&&x| x != 0).count(), 1);
        // After the Nth(1) trigger, later hits leave data untouched.
        let f = FaultInjector::seeded(5);
        f.arm("c", FaultMode::Nth(1));
        let mut x = vec![9u8; 8];
        assert!(f.corrupt("c", &mut x));
        let snapshot = x.clone();
        assert!(!f.corrupt("c", &mut x));
        assert_eq!(x, snapshot);
    }

    #[test]
    fn rearm_resets_counters() {
        let f = FaultInjector::seeded(3);
        f.arm("p", FaultMode::Nth(1));
        assert!(f.trip("p").is_err());
        f.arm("p", FaultMode::Nth(1));
        assert_eq!(f.hits("p"), 0);
        assert!(f.trip("p").is_err(), "fresh counters: first hit fires again");
    }

    #[test]
    fn clones_share_one_registry() {
        let f = FaultInjector::seeded(4);
        let g = f.clone();
        f.arm("p", FaultMode::Nth(2));
        assert!(g.trip("p").is_ok());
        assert!(g.trip("p").is_err(), "hits accumulate across clones");
        assert_eq!(f.trips("p"), 1);
    }
}
