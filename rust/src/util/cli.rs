//! Declarative command-line parsing (clap is unavailable offline).
//!
//! ```text
//! let args = Args::from_vec(vec!["--steps".into(), "100".into(), "--fast".into()]);
//! args.get_usize("steps", 10) == 100 && args.get_flag("fast")
//! ```
//!
//! Conventions: `--key value`, `--key=value`, bare `--flag`, and free
//! positional arguments. Unknown keys are kept and can be audited with
//! [`Args::unused`] so binaries can warn about typos.

use std::cell::RefCell;
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct Args {
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    used: RefCell<Vec<String>>,
}

impl Args {
    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Args {
        // `cargo bench` passes "--bench" to harness=false bench binaries;
        // drop it so benches can share this parser.
        let v: Vec<String> = std::env::args()
            .skip(1)
            .filter(|a| a != "--bench")
            .collect();
        Args::from_vec(v)
    }

    pub fn from_vec(argv: Vec<String>) -> Args {
        let mut kv = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    kv.insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    kv.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(stripped.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args {
            kv,
            flags,
            positional,
            used: RefCell::new(Vec::new()),
        }
    }

    fn mark(&self, key: &str) {
        self.used.borrow_mut().push(key.to_string());
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.kv.get(key).cloned()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        self.kv
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        self.kv
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.mark(key);
        self.kv
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key) || self.kv.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Comma-separated list, e.g. `--ratios 0.5,0.8`.
    pub fn get_list_f64(&self, key: &str, default: &[f64]) -> Vec<f64> {
        self.mark(key);
        match self.kv.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad number {s:?}")))
                .collect(),
        }
    }

    pub fn get_list_usize(&self, key: &str, default: &[usize]) -> Vec<usize> {
        self.mark(key);
        match self.kv.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad integer {s:?}")))
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional argument — used as subcommand by the main binary.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Keys that were provided but never consumed (possible typos).
    pub fn unused(&self) -> Vec<String> {
        let used = self.used.borrow();
        self.kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !used.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::from_vec(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn parses_kv_and_flags() {
        // NOTE: a bare `--flag` followed by a non-dashed token is parsed as
        // a key/value pair — positional args go before flags by convention.
        let a = args("serve extra --steps 50 --ratio=0.8 --fast");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.get_usize("steps", 1), 50);
        assert_eq!(a.get_f64("ratio", 0.0), 0.8);
        assert!(a.get_flag("fast"));
        assert!(!a.get_flag("slow"));
        assert_eq!(a.positional(), &["serve".to_string(), "extra".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = args("");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_str("name", "x"), "x");
        assert_eq!(a.get_list_f64("r", &[0.5]), vec![0.5]);
    }

    #[test]
    fn lists_parse() {
        let a = args("--ratios 0.5,0.8 --sizes=2,4,8");
        assert_eq!(a.get_list_f64("ratios", &[]), vec![0.5, 0.8]);
        assert_eq!(a.get_list_usize("sizes", &[]), vec![2, 4, 8]);
    }

    #[test]
    fn unused_reports_typos() {
        let a = args("--steps 5 --typo 3");
        let _ = a.get_usize("steps", 1);
        assert_eq!(a.unused(), vec!["typo".to_string()]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args("--fast --steps 3");
        assert!(a.get_flag("fast"));
        assert_eq!(a.get_usize("steps", 0), 3);
    }
}
