//! Property-based testing microframework (proptest stand-in).
//!
//! A property is a closure over values drawn from a [`Gen`]; the runner
//! executes `cases` random cases and, on failure, performs greedy
//! shrinking so counterexamples stay readable. Used by
//! `rust/tests/property_invariants.rs` on coordinator and cache invariants.
//!
//! ```text
//! forall("reverse twice is identity", 200, Gen::vec_usize(0..64, 0..32), |xs| {
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     ys == *xs
//! });
//! ```

use super::prng::Pcg64;
use std::ops::Range;

/// A generator of random values paired with a shrinker.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Pcg64) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        gen: impl Fn(&mut Pcg64) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen {
            gen: Box::new(gen),
            shrink: Box::new(shrink),
        }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (no shrinking through the map).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| f(self.sample(rng)), |_| Vec::new())
    }
}

impl Gen<usize> {
    pub fn usize_in(r: Range<usize>) -> Gen<usize> {
        let lo = r.start;
        let hi = r.end;
        Gen::new(
            move |rng| rng.range(lo, hi),
            move |&v| {
                let mut out = Vec::new();
                if v > lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2);
                    out.push(v - 1);
                }
                out.dedup();
                out
            },
        )
    }
}

impl Gen<f64> {
    pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
        Gen::new(
            move |rng| lo + (hi - lo) * rng.uniform(),
            move |&v| {
                let mut out = Vec::new();
                if v != lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2.0);
                }
                out
            },
        )
    }
}

impl Gen<Vec<usize>> {
    /// Vector of usize drawn from `elem`, with random length in `len`.
    pub fn vec_usize(elem: Range<usize>, len: Range<usize>) -> Gen<Vec<usize>> {
        let (elo, ehi) = (elem.start, elem.end);
        let (llo, lhi) = (len.start, len.end);
        Gen::new(
            move |rng| {
                let n = rng.range(llo, lhi.max(llo + 1));
                (0..n).map(|_| rng.range(elo, ehi)).collect()
            },
            move |v: &Vec<usize>| {
                let mut out = Vec::new();
                if v.len() > llo {
                    out.push(v[..v.len() / 2].to_vec()); // front half
                    out.push(v[1..].to_vec()); // drop head
                    let mut t = v.clone();
                    t.pop(); // drop tail
                    out.push(t);
                }
                // shrink elements toward elo
                if let Some((i, _)) = v.iter().enumerate().find(|(_, &x)| x > elo) {
                    let mut t = v.clone();
                    t[i] = elo;
                    out.push(t);
                }
                out
            },
        )
    }
}

/// Tuple combinator.
pub fn zip<A: Clone + 'static, B: Clone + 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    let ga = std::rc::Rc::new(a);
    let gb = std::rc::Rc::new(b);
    let (sa, sb) = (ga.clone(), gb.clone());
    Gen::new(
        move |rng| (ga.sample(rng), gb.sample(rng)),
        move |(x, y)| {
            let mut out: Vec<(A, B)> = Vec::new();
            for xs in sa.shrinks(x) {
                out.push((xs, y.clone()));
            }
            for ys in sb.shrinks(y) {
                out.push((x.clone(), ys));
            }
            out
        },
    )
}

/// Outcome of a property run (exposed for the framework's own tests).
#[derive(Debug, Clone)]
pub enum PropResult<T> {
    Pass { cases: usize },
    Fail { original: T, shrunk: T, shrink_steps: usize },
}

/// Run the property, returning the outcome instead of panicking.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    cases: usize,
    seed: u64,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> bool,
) -> PropResult<T> {
    let mut rng = Pcg64::new(seed);
    for _case in 0..cases {
        let v = gen.sample(&mut rng);
        if prop(&v) {
            continue;
        }
        // Greedy shrink: repeatedly take the first failing shrink candidate.
        let original = v.clone();
        let mut cur = v;
        let mut steps = 0;
        'outer: loop {
            for cand in gen.shrinks(&cur) {
                if !prop(&cand) {
                    cur = cand;
                    steps += 1;
                    if steps > 1000 {
                        break 'outer;
                    }
                    continue 'outer;
                }
            }
            break;
        }
        return PropResult::Fail {
            original,
            shrunk: cur,
            shrink_steps: steps,
        };
    }
    PropResult::Pass { cases }
}

/// Assert-style entry point: panics with the shrunk counterexample.
pub fn forall<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    // Seed from the property name so failures are reproducible but
    // different properties explore different streams.
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    match check(cases, seed, &gen, prop) {
        PropResult::Pass { .. } => {}
        PropResult::Fail {
            original,
            shrunk,
            shrink_steps,
        } => {
            panic!(
                "property {name:?} falsified\n  original: {original:?}\n  shrunk ({shrink_steps} steps): {shrunk:?}\n  (re-run deterministically with seed {seed})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum-commutes", 100, Gen::vec_usize(0..100, 0..20), |xs| {
            let fwd: usize = xs.iter().sum();
            let rev: usize = xs.iter().rev().sum();
            fwd == rev
        });
    }

    #[test]
    fn failing_property_shrinks() {
        // "all vectors are shorter than 5" — counterexample must shrink to
        // something length 5.
        let g = Gen::vec_usize(0..10, 0..40);
        match check(500, 42, &g, |xs| xs.len() < 5) {
            PropResult::Fail { shrunk, .. } => {
                assert_eq!(shrunk.len(), 5, "greedy shrink should reach minimum");
            }
            PropResult::Pass { .. } => panic!("property should fail"),
        }
    }

    #[test]
    fn usize_gen_respects_range() {
        let g = Gen::usize_in(3..17);
        let mut rng = Pcg64::new(1);
        for _ in 0..1000 {
            let v = g.sample(&mut rng);
            assert!((3..17).contains(&v));
        }
    }

    #[test]
    fn zip_shrinks_both_sides() {
        let g = zip(Gen::usize_in(0..100), Gen::usize_in(0..100));
        match check(500, 7, &g, |&(a, b)| a + b < 60) {
            PropResult::Fail { shrunk: (a, b), .. } => {
                assert!(a + b >= 60);
                // shrunk point should be near the boundary
                assert!(a + b <= 130, "({a},{b}) not shrunk");
            }
            _ => panic!("should fail"),
        }
    }
}
