//! Benchmark harness (criterion stand-in).
//!
//! Two modes:
//! * [`Bencher::time`] — classic micro-benchmark: warmup, then timed
//!   iterations with mean/std/percentile reporting.
//! * experiment benches (Tables 1–5, Figures 3–4) use the harness only for
//!   wall-clock bookkeeping and emit their tables via [`crate::util::table`].
//!
//! Every bench binary is `harness = false` and accepts `--fast` (shrinks
//! sample counts for smoke runs) via [`crate::util::cli::Args`].
//!
//! [`Bencher::write_json`] additionally emits machine-readable results
//! (`name → median ns`, plus the git revision) so the perf trajectory is
//! tracked across PRs — `bench_perf_decode` writes
//! `runs/BENCH_perf_decode.json`.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Samples;

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub samples: Samples,
    /// Optional throughput denominator: items processed per iteration.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.samples.mean()
    }

    pub fn report(&self) -> String {
        let base = format!(
            "{:<44} {:>10.3} ms/iter ± {:>8.3}  p50 {:>8.3}  p95 {:>8.3}  (n={})",
            self.name,
            self.samples.mean() * 1e3,
            self.samples.std() * 1e3,
            self.samples.percentile(50.0) * 1e3,
            self.samples.percentile(95.0) * 1e3,
            self.iters,
        );
        match self.items_per_iter {
            Some(k) if self.samples.mean() > 0.0 => {
                format!("{base}  {:>10.1} items/s", k / self.samples.mean())
            }
            _ => base,
        }
    }
}

/// Configurable micro-benchmark runner.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 5,
            max_iters: 200,
            target_time: Duration::from_secs(2),
            results: Vec::new(),
        }
    }

    /// Quick mode for `--fast` smoke runs.
    pub fn fast() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 2,
            max_iters: 5,
            target_time: Duration::from_millis(200),
            results: Vec::new(),
        }
    }

    /// Time `f`, which should perform one full iteration of the workload.
    pub fn time<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.time_items(name, None, &mut f)
    }

    /// Time with a throughput denominator (`items` per iteration).
    pub fn time_throughput<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> &BenchResult {
        self.time_items(name, Some(items), &mut f)
    }

    fn time_items(&mut self, name: &str, items: Option<f64>, f: &mut dyn FnMut()) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Samples::new();
        let t_start = Instant::now();
        let mut iters = 0;
        while iters < self.min_iters
            || (t_start.elapsed() < self.target_time && iters < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
            iters += 1;
        }
        let r = BenchResult {
            name: name.to_string(),
            iters,
            samples,
            items_per_iter: items,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write all recorded results as JSON: `{bench, git_rev, unit,
    /// results: {name: {median_ns, mean_ns, p95_ns, iters}}}`. Used to
    /// track the perf trajectory across PRs. Creates the parent
    /// directory (`runs/` under a fresh checkout or CI workspace) so a
    /// bench never fails at the write-out step.
    pub fn write_json(&self, bench_name: &str, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut results = Json::obj();
        for r in &self.results {
            results.set(
                &r.name,
                Json::from_pairs(vec![
                    ("median_ns", Json::Num(r.samples.percentile(50.0) * 1e9)),
                    ("mean_ns", Json::Num(r.samples.mean() * 1e9)),
                    ("p95_ns", Json::Num(r.samples.percentile(95.0) * 1e9)),
                    ("iters", Json::Num(r.iters as f64)),
                ]),
            );
        }
        let root = Json::from_pairs(vec![
            ("bench", Json::Str(bench_name.to_string())),
            (
                "git_rev",
                Json::Str(git_rev().unwrap_or_else(|| "unknown".to_string())),
            ),
            ("unit", Json::Str("ns".to_string())),
            ("results", results),
        ]);
        std::fs::write(path, root.to_string_pretty())?;
        Ok(())
    }
}

/// Short git revision of the working tree, if available.
pub fn git_rev() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let rev = String::from_utf8(out.stdout).ok()?.trim().to_string();
    if rev.is_empty() {
        None
    } else {
        Some(rev)
    }
}

/// Prevent the optimizer from discarding a computed value
/// (std::hint::black_box wrapper so call sites read like criterion).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Shared preamble printed by every bench binary (environment provenance
/// for EXPERIMENTS.md).
pub fn print_bench_header(name: &str, paper_ref: &str) {
    println!("==============================================================");
    println!("bench: {name}");
    println!("reproduces: {paper_ref}");
    println!(
        "host: {} core(s), rust {}, seed-controlled",
        super::threadpool::ThreadPool::available_parallelism(),
        option_env!("CARGO_PKG_RUST_VERSION").unwrap_or("stable"),
    );
    println!("==============================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 3,
            target_time: Duration::from_millis(1),
            results: Vec::new(),
        };
        let mut acc = 0u64;
        b.time("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].iters, 3);
        assert!(b.results()[0].mean_s() >= 0.0);
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bencher::fast();
        let r = b.time_throughput("noop", 100.0, || {
            black_box(0u64);
        });
        assert!(r.report().contains("items/s"));
    }

    #[test]
    fn json_results_roundtrip() {
        let mut b = Bencher::fast();
        b.time("spin/json", || {
            black_box(1u64);
        });
        let dir = std::env::temp_dir().join("cskv_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        b.write_json("bench_test", &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.at("bench").and_then(Json::as_str), Some("bench_test"));
        assert!(j.at("git_rev").and_then(Json::as_str).is_some());
        let median = j
            .at("results.spin/json")
            .and_then(|r| r.get("median_ns"))
            .and_then(Json::as_f64)
            .expect("median_ns recorded");
        assert!(median >= 0.0);
    }
}
