//! Aligned ASCII table printer used by benches to emit paper-style tables.

/// Builder for an aligned table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("| ");
            for i in 0..ncol {
                line.push_str(&format!("{:width$} | ", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let sep: String = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('|');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// CSV form for EXPERIMENTS.md appendices.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to other run outputs.
    pub fn save_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Format helper: 2-decimal accuracy cell matching the paper's tables.
pub fn acc(x: f64) -> String {
    format!("{x:.2}")
}

/// Format helper: ratio as percent.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format helper: bytes with binary units.
pub fn bytes(n: usize) -> String {
    let units = ["B", "KiB", "MiB", "GiB"];
    let mut x = n as f64;
    let mut u = 0;
    while x >= 1024.0 && u + 1 < units.len() {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n}B")
    } else {
        format!("{x:.2}{}", units[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "acc"]);
        t.row_strs(&["CSKV", "0.92"]);
        t.row_strs(&["StreamingLLM", "0.06"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("| CSKV         | 0.92 |"));
        let lines: Vec<&str> = r.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_strs(&["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn format_helpers() {
        assert_eq!(acc(0.923), "0.92");
        assert_eq!(pct(0.8), "80.0%");
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(2048), "2.00KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.00MiB");
    }
}
