//! Streaming statistics and percentile summaries for benchmarks and the
//! coordinator's latency metrics.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// A recorded sample set with exact percentiles (sorts on query).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Samples { xs: Vec::new() }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn extend_from(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    /// Linear-interpolated percentile, `p` in `[0, 100]`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Short human summary: `mean ± std [p50 p95 p99]`.
    pub fn summary(&self, unit: &str) -> String {
        format!(
            "{:.3}{u} ± {:.3} [p50 {:.3}{u} p95 {:.3}{u} p99 {:.3}{u}] n={}",
            self.mean(),
            self.std(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.len(),
            u = unit,
        )
    }
}

/// Fixed-bin histogram over `[lo, hi)` used for figure-style dumps.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let b = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[b.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// ASCII bar rendering (used by the figure benches).
    pub fn render(&self, width: usize) -> String {
        let maxc = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, c) in self.bins.iter().enumerate() {
            let bl = self.lo + (self.hi - self.lo) * i as f64 / self.bins.len() as f64;
            let bh = self.lo + (self.hi - self.lo) * (i + 1) as f64 / self.bins.len() as f64;
            let bar = "#".repeat(((*c as f64 / maxc as f64) * width as f64).round() as usize);
            out.push_str(&format!("[{bl:10.3},{bh:10.3}) {c:8} {bar}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for x in xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Samples::new();
        for x in [10.0, 20.0, 30.0, 40.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 40.0);
        assert!((s.percentile(50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_sample() {
        let mut s = Samples::new();
        s.push(5.0);
        assert_eq!(s.percentile(99.0), 5.0);
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }
}
