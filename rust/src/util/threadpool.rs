//! Minimal scoped thread pool + `parallel_for` (rayon stand-in).
//!
//! The container exposes a single core, so defaults degrade gracefully to
//! sequential execution, but the pool is fully functional and is exercised
//! by tests with multiple workers — the coordinator uses it for background
//! work and the tensor layer uses [`parallel_for`] for row-blocked matmul.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool executing boxed jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    /// `n == 0` is clamped to 1.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        job();
                        let (lock, cv) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        cv.notify_all();
                    }
                    Err(_) => break,
                }
            }));
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Number of logical CPUs (reads `/proc/cpuinfo`, falls back to 1).
    pub fn available_parallelism() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx.as_ref().unwrap().send(Box::new(f)).unwrap();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for `i in 0..n`, split across up to `threads` scoped workers.
///
/// Uses `std::thread::scope`, so `f` may borrow from the caller. With
/// `threads <= 1` (the default on this 1-core container) it runs inline
/// with zero overhead.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Chunked variant: calls `f(lo, hi)` on disjoint ranges covering `0..n`.
pub fn parallel_chunks<F: Fn(usize, usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_for(97, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_chunks_partition() {
        let hits: Vec<AtomicU64> = (0..50).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(50, 3, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_sequential_fallback() {
        let mut seen = vec![false; 10];
        // threads=1 runs inline; borrowing mutably is fine via RefCell-free trick
        let cells: Vec<AtomicU64> = (0..10).map(|_| AtomicU64::new(0)).collect();
        parallel_for(10, 1, |i| {
            cells[i].store(1, Ordering::SeqCst);
        });
        for (i, c) in cells.iter().enumerate() {
            seen[i] = c.load(Ordering::SeqCst) == 1;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
