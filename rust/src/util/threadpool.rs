//! Minimal persistent thread pool + `parallel_for` (rayon stand-in).
//!
//! The container exposes a single core, so defaults degrade gracefully to
//! sequential execution, but the pool is fully functional and is exercised
//! by tests with multiple workers — the coordinator uses it for background
//! work and the tensor layer uses [`parallel_for`] for row-blocked matmul.
//!
//! ## Pool reuse
//!
//! [`parallel_for`] / [`parallel_chunks`] dispatch to one process-wide
//! persistent [`ThreadPool`] (grown on demand to the widest width any call
//! requests) instead of spawning scoped OS threads per call: a serving
//! decode round issues hundreds of small parallel regions per second, and
//! per-call `thread::spawn` overhead dominated at small context lengths
//! (the ROADMAP "NUMA / pool reuse" item; `bench_perf_serving` records the
//! pooled-vs-scoped A/B). The calling thread always participates in the
//! work loop, so a call makes progress even when every pool worker is
//! busy, and a parallel region entered *from* a pool worker runs inline —
//! nested calls can never deadlock on pool capacity. The pre-pool
//! implementations are kept as [`parallel_for_scoped`] /
//! [`parallel_chunks_scoped`] (bench baseline). Work distribution is
//! unchanged, so results stay bit-identical to the scoped path at every
//! width.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool executing boxed jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    /// `n == 0` is clamped to 1.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        job();
                        let (lock, cv) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        cv.notify_all();
                    }
                    Err(_) => break,
                }
            }));
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Number of logical CPUs (reads `/proc/cpuinfo`, falls back to 1).
    pub fn available_parallelism() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx.as_ref().unwrap().send(Box::new(f)).unwrap();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Process-wide default worker count consulted by call sites whose
/// `threads` knob is 0 ("auto"): the serving coordinator and the eval
/// harness both size engine parallelism from this single value, so every
/// prefill/GEMM in the process shares one pool width instead of each
/// subsystem implicitly serializing. Defaults to 1 (serial) — results are
/// bit-identical at any width, so this is purely a performance knob.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the process-wide default worker count (`0` and `1` both mean
/// serial). Called once at startup by whoever owns the `--threads` flag
/// (`cskv serve`, the benches, the eval harness).
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// The current process-wide default worker count.
pub fn global_threads() -> usize {
    GLOBAL_THREADS.load(Ordering::Relaxed)
}

/// Resolve a config-level `threads` knob: `0` means "use the process
/// default" ([`global_threads`]), any other value is taken literally.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        global_threads()
    } else {
        threads
    }
}

/// Raw-pointer wrapper that lets scoped workers write *disjoint* regions
/// of one shared buffer (output rows of a GEMM, per-task scratch slots).
///
/// Safety discipline (callers must uphold, the wrapper cannot check):
/// every concurrent task derives slices only from ranges it exclusively
/// owns, and the underlying buffer outlives the parallel region. All
/// uses in this crate partition by row index, so ranges are disjoint by
/// construction.
pub(crate) struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Mutable slice `[off, off + len)` of the wrapped buffer.
    ///
    /// # Safety
    /// The range must be disjoint from every range any concurrent task
    /// touches, and in bounds of the original allocation.
    #[inline]
    #[allow(clippy::mut_from_ref)] // aliasing is governed by the contract above
    pub unsafe fn slice_mut<'a>(&self, off: usize, len: usize) -> &'a mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(off), len)
    }
}

/// Run `f(i, row_i)` over every row of a row-major `rows × cols` buffer,
/// rows split dynamically across up to `threads` scoped workers.
///
/// This is the safe entry point for embarrassingly row-parallel kernels
/// (RMSNorm, RoPE, SiLU): each row is handed out exactly once, so the
/// mutable accesses are disjoint and the result is bit-identical to the
/// serial loop regardless of thread count.
pub fn parallel_rows<F: Fn(usize, &mut [f32]) + Sync>(
    data: &mut [f32],
    rows: usize,
    cols: usize,
    threads: usize,
    f: F,
) {
    assert!(data.len() >= rows * cols, "row buffer too small");
    let threads = threads.max(1);
    if threads == 1 || rows <= 1 {
        for (i, row) in data.chunks_exact_mut(cols.max(1)).take(rows).enumerate() {
            f(i, row);
        }
        return;
    }
    let ptr = SendPtr(data.as_mut_ptr());
    parallel_for(rows, threads, |i| {
        // Safety: `parallel_for` hands out each `i` exactly once, so the
        // row ranges are disjoint and in bounds.
        let row = unsafe { ptr.slice_mut(i * cols, cols) };
        f(i, row);
    });
}

/// Process-wide pool backing [`parallel_for`] / [`parallel_chunks`].
/// Created lazily at the first multi-worker call and grown (never shrunk)
/// whenever a call requests more helpers than the pool holds.
static SHARED_POOL: Mutex<Option<Arc<ThreadPool>>> = Mutex::new(None);

fn shared_pool(min_workers: usize) -> Arc<ThreadPool> {
    let mut g = SHARED_POOL.lock().unwrap();
    if let Some(p) = g.as_ref() {
        if p.size() >= min_workers {
            return Arc::clone(p);
        }
    }
    let n = min_workers.max(g.as_ref().map_or(0, |p| p.size()));
    let p = Arc::new(ThreadPool::new(n));
    *g = Some(Arc::clone(&p));
    p
}

thread_local! {
    /// True while this thread is executing a pooled parallel region's job.
    /// A nested `parallel_for` on such a thread runs inline instead of
    /// re-entering the pool: with every worker potentially blocked on its
    /// own nested region, queued helper jobs could otherwise never run.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

/// Shared state of one pooled parallel region. Lives on the callers' Arc
/// until the last helper job drops it; `f` is a lifetime-erased borrow of
/// the caller's closure, valid because the caller blocks on `remaining`
/// before returning.
struct PooledRun {
    counter: AtomicUsize,
    n: usize,
    f: &'static (dyn Fn(usize) + Sync),
    remaining: Mutex<usize>,
    done: Condvar,
    /// First panic payload from any participant, re-raised on the caller
    /// with [`std::panic::resume_unwind`] so the original message and
    /// location survive (matching the scoped and inline paths).
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl PooledRun {
    /// Drain indices from the shared counter until exhausted. Catches
    /// panics so a helper can always report completion (the payload is
    /// re-raised on the calling thread).
    fn drive(&self) {
        if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let i = self.counter.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            (self.f)(i);
        })) {
            let mut slot = self.panic_payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }
}

/// Run `f(i)` for `i in 0..n`, the indices drained by the calling thread
/// plus up to `threads - 1` helpers from the shared persistent pool.
///
/// `f` may borrow from the caller: the call blocks until every helper has
/// finished. With `threads <= 1` (the default on this 1-core container),
/// or when called from inside a pool job (nested parallelism), it runs
/// inline with zero overhead. Each index is executed exactly once, so the
/// result is bit-identical at every width. A panic inside `f` is
/// re-raised on the calling thread after the region drains.
///
/// Caveat: the pool's job queue is FIFO and shared, so a caller's return
/// can wait behind *other* callers' queued jobs even when its own
/// indices are already drained (the helper jobs must at least start to
/// report completion). With one serving worker plus batch-level
/// parallelism this doesn't bite; if many threads issue tiny regions
/// concurrently, prefer [`parallel_for_scoped`] for the latency-critical
/// ones.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || IN_POOL_JOB.with(|c| c.get()) {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let helpers = threads - 1;
    let f_ref: &(dyn Fn(usize) + Sync) = &f;
    // Safety: the lifetime is erased only for the pool jobs below, and
    // this function does not return until `remaining == 0`, i.e. until no
    // job can touch `f` again (dropping the Arc afterwards never reads
    // the borrow).
    let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f_ref) };
    let run = Arc::new(PooledRun {
        counter: AtomicUsize::new(0),
        n,
        f: f_static,
        remaining: Mutex::new(helpers),
        done: Condvar::new(),
        panic_payload: Mutex::new(None),
    });
    let pool = shared_pool(helpers);
    for _ in 0..helpers {
        let r = Arc::clone(&run);
        pool.execute(move || {
            IN_POOL_JOB.with(|c| c.set(true));
            r.drive();
            IN_POOL_JOB.with(|c| c.set(false));
            let mut g = r.remaining.lock().unwrap();
            *g -= 1;
            r.done.notify_all();
        });
    }
    // The caller always participates: the region completes even if every
    // pool worker is busy with other callers' work.
    run.drive();
    let mut g = run.remaining.lock().unwrap();
    while *g > 0 {
        g = run.done.wait(g).unwrap();
    }
    drop(g);
    if let Some(payload) = run.panic_payload.lock().unwrap().take() {
        std::panic::resume_unwind(payload);
    }
}

/// Chunked variant: calls `f(lo, hi)` on disjoint ranges covering `0..n`,
/// partitioned exactly as [`parallel_chunks_scoped`] and executed on the
/// shared pool via [`parallel_for`].
pub fn parallel_chunks<F: Fn(usize, usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    let n_chunks = n.div_ceil(chunk);
    parallel_for(n_chunks, threads, |t| {
        let lo = t * chunk;
        let hi = (lo + chunk).min(n);
        f(lo, hi);
    });
}

/// The pre-pool `parallel_for`: spawns scoped OS threads per call. Kept
/// verbatim as the baseline for the pool-reuse A/B in
/// `bench_perf_serving` — production call sites use [`parallel_for`].
pub fn parallel_for_scoped<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// The pre-pool `parallel_chunks` (scoped-spawn baseline, see
/// [`parallel_for_scoped`]).
pub fn parallel_chunks_scoped<F: Fn(usize, usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_for(97, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_chunks_partition() {
        let hits: Vec<AtomicU64> = (0..50).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(50, 3, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_and_chunks_empty_range() {
        // n = 0: no worker may ever observe an index; `parallel_chunks`
        // degrades to a single `f(0, 0)` call on the empty range.
        let calls = AtomicU64::new(0);
        parallel_for(0, 4, |_| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 0);

        let chunk_calls = AtomicU64::new(0);
        let covered = AtomicU64::new(0);
        parallel_chunks(0, 4, |lo, hi| {
            chunk_calls.fetch_add(1, Ordering::SeqCst);
            covered.fetch_add((hi - lo) as u64, Ordering::SeqCst);
        });
        assert_eq!(chunk_calls.load(Ordering::SeqCst), 1);
        assert_eq!(covered.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn parallel_for_and_chunks_fewer_items_than_threads() {
        // n < threads: the worker count clamps to n; every index is still
        // visited exactly once and ranges still partition 0..n.
        let hits: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
        parallel_for(3, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));

        let hits: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(3, 8, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_rows_visits_each_row_once_any_width() {
        for threads in [1usize, 2, 8] {
            let (rows, cols) = (7usize, 5usize);
            let mut data = vec![0.0f32; rows * cols];
            parallel_rows(&mut data, rows, cols, threads, |i, row| {
                for v in row.iter_mut() {
                    *v += (i + 1) as f32;
                }
            });
            for i in 0..rows {
                assert!(
                    data[i * cols..(i + 1) * cols].iter().all(|&v| v == (i + 1) as f32),
                    "threads={threads} row {i}"
                );
            }
        }
        // Degenerate: zero rows must not touch the buffer or call f.
        let mut empty: Vec<f32> = Vec::new();
        parallel_rows(&mut empty, 0, 4, 3, |_, _| panic!("no rows to visit"));
    }

    #[test]
    fn global_threads_knob_roundtrip() {
        // Note: process-global — keep the default restored for other tests.
        let before = global_threads();
        set_global_threads(6);
        assert_eq!(global_threads(), 6);
        assert_eq!(resolve_threads(0), 6);
        assert_eq!(resolve_threads(3), 3);
        set_global_threads(0); // clamps to 1
        assert_eq!(global_threads(), 1);
        set_global_threads(before);
    }

    #[test]
    fn pooled_and_scoped_visit_identical_ranges() {
        for threads in [2usize, 3, 8] {
            for n in [1usize, 5, 50, 97] {
                let pooled: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                parallel_for(n, threads, |i| {
                    pooled[i].fetch_add(1, Ordering::SeqCst);
                });
                let scoped: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                parallel_for_scoped(n, threads, |i| {
                    scoped[i].fetch_add(1, Ordering::SeqCst);
                });
                for i in 0..n {
                    assert_eq!(pooled[i].load(Ordering::SeqCst), 1, "pooled n={n} t={threads}");
                    assert_eq!(scoped[i].load(Ordering::SeqCst), 1, "scoped n={n} t={threads}");
                }
                // Chunk partitions must match the scoped baseline exactly.
                let mut want: Vec<(usize, usize)> = Vec::new();
                let chunk = n.div_ceil(threads.min(n));
                let mut lo = 0;
                while lo < n {
                    want.push((lo, (lo + chunk).min(n)));
                    lo += chunk;
                }
                let got = Mutex::new(Vec::new());
                parallel_chunks(n, threads, |lo, hi| {
                    got.lock().unwrap().push((lo, hi));
                });
                let mut got = got.into_inner().unwrap();
                got.sort_unstable();
                assert_eq!(got, want, "chunks n={n} t={threads}");
            }
        }
    }

    /// Nested parallel regions must complete (inner regions run inline on
    /// pool workers) — the classic fixed-pool deadlock shape.
    #[test]
    fn nested_parallel_for_completes() {
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        parallel_for(8, 4, |outer| {
            parallel_for(8, 4, |inner| {
                hits[outer * 8 + inner].fetch_add(1, Ordering::SeqCst);
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    /// A panic inside `f` must surface on the calling thread — with its
    /// original payload — without wedging the shared pool for later
    /// callers.
    #[test]
    fn pooled_parallel_for_propagates_panics() {
        let res = std::panic::catch_unwind(|| {
            parallel_for(16, 4, |i| {
                if i == 7 {
                    panic!("injected");
                }
            });
        });
        let payload = res.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "injected", "original panic payload must survive");
        // Pool still serves subsequent regions.
        let c = AtomicU64::new(0);
        parallel_for(16, 4, |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn parallel_for_sequential_fallback() {
        let mut seen = vec![false; 10];
        // threads=1 runs inline; borrowing mutably is fine via RefCell-free trick
        let cells: Vec<AtomicU64> = (0..10).map(|_| AtomicU64::new(0)).collect();
        parallel_for(10, 1, |i| {
            cells[i].store(1, Ordering::SeqCst);
        });
        for (i, c) in cells.iter().enumerate() {
            seen[i] = c.load(Ordering::SeqCst) == 1;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
