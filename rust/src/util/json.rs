//! Minimal JSON parser and writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number formats. Used for the
//! AOT artifact manifest (`artifacts/manifest.json`), experiment result
//! files and coordinator config.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- constructors -----------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v);
        }
        Json::Obj(m)
    }

    // ----- accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.at("a.b.c")` — dotted-path lookup.
    pub fn at(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = cur.get(seg)?;
        }
        Some(cur)
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // ----- serialization ------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !map.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }

    // ----- parsing -------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at("c.d").unwrap().as_f64().unwrap(), -2500.0);
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parse_numbers() {
        for (s, n) in [("0", 0.0), ("-7", -7.0), ("3.25", 3.25), ("1e2", 100.0), ("-1.5E-2", -0.015)]
        {
            assert_eq!(Json::parse(s).unwrap().as_f64().unwrap(), n, "{s}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t ctrl\u{1}".to_string());
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::from_pairs(vec![
            ("name", "cskv".into()),
            ("ratios", vec![0.5f64, 0.8].into()),
            ("nested", Json::from_pairs(vec![("k", 1usize.into())])),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::obj().to_string_compact(), "{}");
    }
}
