//! API-compatible **stub** of the `xla_extension` PJRT bindings.
//!
//! The container has no native XLA/PJRT toolchain, so this vendored crate
//! provides just enough surface for `cskv::runtime::client` to compile.
//! Every entry point that would need the real runtime fails cleanly at
//! *runtime* ([`PjRtClient::cpu`] returns an error), which the callers
//! already handle: the PJRT tests and bench sections skip when artifacts
//! are missing, and `Runtime::load` propagates the error otherwise.
//!
//! Swap this path dependency for the real `xla` bindings in
//! `Cargo.toml` to run the AOT artifacts.

use std::fmt;

/// Error type matching the real bindings' role (convertible to
/// `anyhow::Error` through `std::error::Error`).
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(stub): {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: XLA/PJRT is not available in this build (stub crate; link the real xla_extension bindings)"
    )))
}

/// Element types a [`Literal`] can hold.
pub trait ElementType: Copy + 'static {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u8 {}

/// Host-side tensor literal (stub: carries no data).
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar<T: ElementType>(_v: T) -> Literal {
        Literal { _private: () }
    }

    pub fn vec1<T: ElementType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// HLO module handle (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (stub — construction always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
        assert!(Literal::scalar(1.0f32).to_vec::<f32>().is_err());
        let _ = Literal::vec1(&[1i32, 2]).reshape(&[2]).unwrap();
    }
}
