//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The container builds without network access, so the subset of anyhow
//! this repository actually uses is vendored here: [`Error`], [`Result`],
//! and the [`anyhow!`], [`bail!`], [`ensure!`] macros. Like the real
//! crate, `Error` deliberately does **not** implement `std::error::Error`
//! so the blanket `From<E: std::error::Error>` impl (which powers `?`)
//! does not conflict with the reflexive `From<T> for T`.

use std::fmt;

/// A dynamic error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap a concrete error, preserving it as the source.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Prepend context to the message (mirrors `anyhow::Context`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut next = self
            .source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for (i, cause) in self.chain().enumerate() {
            if i == 0 {
                write!(f, "\n\nCaused by:")?;
            }
            write!(f, "\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/cskv")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.chain().count() >= 1);
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let n = 3;
        let e = anyhow!("bad count {n} for {}", "layer");
        assert_eq!(e.to_string(), "bad count 3 for layer");

        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("seven is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(7).unwrap_err().to_string().contains("seven"));
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn g(x: usize) -> Result<()> {
            ensure!(x % 2 == 0);
            Ok(())
        }
        assert!(g(3).unwrap_err().to_string().contains("x % 2 == 0"));
    }

    #[test]
    fn context_prepends() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
