//! §Perf — attention-aware multi-tier KV paging: block-granular
//! spill/promote with prefetch-overlapped restores.
//!
//! Three experiments, matching the pager's three claims:
//!
//! 1. **Storm bit-identity** — for *every* policy variant (full, CSKV
//!    fp32/int4, StreamingLLM, H2O, ASVD) a preemption storm (a hot
//!    long generation repeatedly swapped out through the disk-backed
//!    pager to admit bursts of shorts) must stream tokens
//!    **bit-identical** to the never-preempted direct-engine oracle.
//!    Paging placement and prefetch change latency, never bytes.
//! 2. **Prefetch overlap** — at the pager level, the same spilled
//!    working set is restored once synchronously (prefetch off: every
//!    `take` blocks on retried reads) and once overlapped (prefetch
//!    issued, a stand-in decode round spins, then `take` claims landed
//!    blocks). Acceptance: the overlapped restores hide **>= 70%** of
//!    the synchronous restore-stall (`PagerStats::restore_stall_s`,
//!    the wall-clock takes spend blocked on pager I/O).
//! 3. **Eviction-scoring A/B** — equal warm budgets, a working set
//!    where half the sequences carry high attention mass (the ones the
//!    workload resumes) and half carry near-zero mass (cancelled).
//!    Acceptance: `attention` scoring promotes (restores from disk)
//!    **fewer bytes** than the `age` baseline, because it spilled the
//!    low-mass blocks and kept the resumed sequences' blocks warm.
//!
//! Experiments 1 and 3 are deterministic and asserted in every mode;
//! the timing gate of experiment 2 is asserted in full runs and
//! report-only under `--fast` (CI smoke).
//!
//! Like the other perf benches the model comes from `ModelWeights::init`
//! so it runs anywhere (CI included; no pretrained weights needed).
//! Results land in `runs/BENCH_perf_paging.json`.
//!
//! Run: `cargo bench --bench bench_perf_paging [-- --fast]`

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use cskv::baselines::{AsvdCache, H2oCache, StreamingLlmCache};
use cskv::compress::{LayerFactors, LowRankFactors, ModelFactors};
use cskv::coordinator::pager::DEFAULT_BLOCK_BYTES;
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{
    Coordinator, CoordinatorConfig, EvictionScoring, Pager, PagerConfig, PagerStats,
    RustSequenceBackend, SchedulerKind,
};
use cskv::kvcache::snapshot::tags;
use cskv::kvcache::{split_blocks, CskvCache, CskvConfig, FullCache, KvCachePolicy, KvSnapshot, QuantMode};
use cskv::model::{engine::Engine, ModelConfig, ModelWeights};
use cskv::tensor::Mat;
use cskv::util::bench::{black_box, git_rev, print_bench_header};
use cskv::util::cli::Args;
use cskv::util::json::Json;
use cskv::util::prng::Pcg64;
use cskv::util::table::Table;

const WEIGHT_SEED: u64 = 5;
/// The proven preemption geometry (scheduler + chaos tests): a long
/// generation whose projection fills the budget, so each arriving short
/// forces a swap through the pager.
const LONG_PROMPT: [usize; 6] = [1, 7, 9, 2, 30, 41];
const SHORT_PROMPT: [usize; 3] = [3, 5, 8];

fn make_engine() -> Engine {
    Engine::new(Arc::new(ModelWeights::init(&ModelConfig::test_small(), WEIGHT_SEED)))
}

/// Low-rank factors matching the `test_small` engine geometry — same
/// construction as the drain-migrate sweep, so the CSKV/ASVD states
/// here correspond to proven snapshot round-trip geometry.
fn engine_factors(rank: usize) -> Arc<ModelFactors> {
    let d = ModelConfig::test_small().d_model;
    let mut rng = Pcg64::new(rank as u64 * 77 + 5);
    let mut mk = move || {
        LowRankFactors::new(
            Mat::randn(d, rank, 0.2, &mut rng),
            Mat::randn(rank, d, 0.2, &mut rng),
        )
    };
    Arc::new(ModelFactors {
        layers: (0..2).map(|_| LayerFactors { k: mk(), v: mk() }).collect(),
        provenance: "bench-paging".into(),
    })
}

/// The six policy variants, as capture-free constructors so the
/// coordinator backends and the oracle build identical fresh instances.
fn policies() -> Vec<(&'static str, fn() -> Box<dyn KvCachePolicy>)> {
    fn full() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(FullCache::new(c.n_layers, c.d_model))
    }
    fn cskv_fp32() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(CskvCache::new(
            engine_factors(8),
            c.d_model,
            CskvConfig { window: 6, quant: QuantMode::None },
        ))
    }
    fn cskv_int4() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(CskvCache::new(
            engine_factors(8),
            c.d_model,
            CskvConfig { window: 6, quant: QuantMode::Int4 },
        ))
    }
    fn streaming() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(StreamingLlmCache::new(c.n_layers, c.d_model, 2, 12))
    }
    fn h2o() -> Box<dyn KvCachePolicy> {
        let c = ModelConfig::test_small();
        Box::new(H2oCache::new(c.n_layers, c.d_model, 10))
    }
    fn asvd() -> Box<dyn KvCachePolicy> {
        Box::new(AsvdCache::new(engine_factors(8)))
    }
    vec![
        ("full", full as fn() -> Box<dyn KvCachePolicy>),
        ("cskv-fp32", cskv_fp32),
        ("cskv-int4", cskv_int4),
        ("streaming-llm", streaming),
        ("h2o", h2o),
        ("asvd", asvd),
    ]
}

fn setup(mk: fn() -> Box<dyn KvCachePolicy>) -> Setup {
    Box::new(move || {
        let engine = make_engine();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(RustSequenceBackend::new(engine.clone(), mk())))
        });
        Ok(factory)
    })
}

fn tmp(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cskv-bench-paging-{label}-{}", std::process::id()))
}

struct StormCell {
    preemptions: u64,
    restores: u64,
    prefetch_hits: u64,
    prefetch_misses: u64,
    io_stall_s: f64,
    wall_s: f64,
}

/// One preemption storm under `mk`-policy backends: a long generation
/// goes hot, then `storms` short requests each force a swap through the
/// disk-backed pager. Asserts the acceptance criterion inline: both
/// streams bit-identical to the never-preempted oracle, no failures,
/// every swap resumed.
fn run_storm(
    name: &str,
    mk: fn() -> Box<dyn KvCachePolicy>,
    long_n: usize,
    storms: usize,
) -> anyhow::Result<StormCell> {
    let short_n = 2usize;
    // Oracles: the undisturbed generations under this exact policy.
    let engine = make_engine();
    let want_long = engine.generate(&LONG_PROMPT, long_n, mk().as_mut()).0;
    let want_short = engine.generate(&SHORT_PROMPT, short_n, mk().as_mut()).0;

    // Budget prices one long projection plus half a short under this
    // policy's own compression: the long fits alone, long + short never
    // do, so every short admission preempts.
    let pricer = mk();
    let budget = pricer.kv_bytes_projected(LONG_PROMPT.len() + long_n)
        + pricer.kv_bytes_projected(SHORT_PROMPT.len() + short_n) / 2;
    drop(pricer);

    let dir = tmp(&format!("storm-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    let coord = Coordinator::start(
        setup(mk),
        CoordinatorConfig {
            max_batch: 4,
            kv_budget_bytes: Some(budget),
            scheduler: SchedulerKind::Preemptive,
            // Bare disk dir = warm budget 0: every parked block run hits
            // the disk tier, so restores exercise prefetch + promote.
            disk_dir: Some(dir.clone()),
            ..Default::default()
        },
    );

    let t0 = Instant::now();
    let long_rx = coord.submit(LONG_PROMPT.to_vec(), long_n);
    let mut long_resp = None;
    for _ in 0..storms {
        // Wait for the long sequence to be resident and hot again (or
        // finished — then the storm is over early).
        let t_wait = Instant::now();
        loop {
            if let Ok(r) = long_rx.try_recv() {
                long_resp = Some(r);
                break;
            }
            let m = coord.metrics();
            if m.cold_bytes_current() == 0 && m.kv_bytes_current() > 0 {
                break;
            }
            anyhow::ensure!(
                t_wait.elapsed().as_secs() < 60,
                "{name}: long sequence neither hot nor finished"
            );
            std::thread::yield_now();
        }
        if long_resp.is_some() {
            break;
        }
        let short = coord.submit_wait(SHORT_PROMPT.to_vec(), short_n);
        anyhow::ensure!(short.error.is_none(), "{name}: short failed: {:?}", short.error);
        assert_eq!(short.tokens, want_short, "{name}: co-scheduled short must be bit-identical");
    }
    let long = match long_resp {
        Some(r) => r,
        None => long_rx.recv()?,
    };
    let wall_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(long.error.is_none(), "{name}: long failed: {:?}", long.error);
    assert_eq!(
        long.tokens, want_long,
        "{name}: storm-paged stream must be bit-identical to the never-preempted oracle"
    );

    let snap = coord.shutdown();
    assert_eq!(snap.requests_failed, 0, "{name}: paging must not fail requests");
    assert!(snap.preemptions >= 1, "{name}: the storm never preempted");
    assert_eq!(snap.restores, snap.preemptions, "{name}: every swap must resume");
    assert_eq!(snap.cold_bytes_current, 0, "{name}: pager must drain to zero");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(StormCell {
        preemptions: snap.preemptions,
        restores: snap.restores,
        prefetch_hits: snap.pager.prefetch_hits,
        prefetch_misses: snap.pager.prefetch_misses,
        io_stall_s: snap.pager.restore_stall_s,
        wall_s,
    })
}

struct OverlapCell {
    io_stall_s: f64,
    take_wall_s: f64,
    hits: u64,
    misses: u64,
}

/// Spill `n_seqs` synthetic sequences through a disk-backed pager, then
/// restore them all. With `prefetch` the restores are issued up front
/// and a stand-in decode round spins for `compute_s` before the takes —
/// the overlap the worker loop gets for free from
/// `prefetch_expected_resumes`. Without it every take blocks on
/// synchronous reads (the baseline).
fn run_overlap(dir: &Path, n_seqs: u64, payload: usize, prefetch: bool, compute_s: f64) -> OverlapCell {
    let _ = std::fs::remove_dir_all(dir);
    let mut pager = Pager::new(PagerConfig {
        disk_dir: Some(dir.to_path_buf()),
        warm_budget_bytes: None, // bare disk dir: every block spills
        block_bytes: DEFAULT_BLOCK_BYTES,
        scoring: EvictionScoring::Attention,
        prefetch,
    });
    for id in 0..n_seqs {
        let snap = KvSnapshot::new(tags::FULL, vec![(id as u8).wrapping_add(1); payload]);
        pager.put(id, &snap, None).expect("park");
    }
    if prefetch {
        let ids: Vec<u64> = (0..n_seqs).collect();
        pager.prefetch(&ids);
        // The decode round the background restores overlap with.
        let t0 = Instant::now();
        let mut x = 0u64;
        while t0.elapsed().as_secs_f64() < compute_s {
            x = black_box(x.wrapping_mul(6364136223846793005).wrapping_add(1));
        }
        black_box(x);
    }
    let t0 = Instant::now();
    for id in 0..n_seqs {
        let snap = pager.take(id).expect("restore");
        assert_eq!(snap.payload().len(), payload, "restored payload intact");
        black_box(snap.payload()[0]);
    }
    let take_wall_s = t0.elapsed().as_secs_f64();
    let s = pager.stats();
    let _ = std::fs::remove_dir_all(dir);
    OverlapCell {
        io_stall_s: s.restore_stall_s,
        take_wall_s,
        hits: s.prefetch_hits,
        misses: s.prefetch_misses,
    }
}

/// Equal-budget eviction-scoring A/B. Eight sequences park through a
/// warm tier budgeted at half the working set: the even ids carry high
/// attention mass and are later resumed; the odd ids carry near-zero
/// mass and are cancelled. Returns the pager's counters — the promote
/// volume is the restore traffic the scoring choice caused.
fn run_scoring(dir: &Path, scoring: EvictionScoring, payload: usize) -> PagerStats {
    let _ = std::fs::remove_dir_all(dir);
    let block = 8 * 1024;
    // At-rest size of one parked sequence (block payloads + frames).
    let enc = KvSnapshot::new(tags::FULL, vec![0u8; payload]).encode();
    let at_rest: usize = split_blocks(&enc, block).iter().map(|b| b.size_bytes()).sum();
    let mut pager = Pager::new(PagerConfig {
        disk_dir: Some(dir.to_path_buf()),
        warm_budget_bytes: Some(4 * at_rest), // half of the 8-sequence set
        block_bytes: block,
        scoring,
        prefetch: false, // synchronous restores: promote volume only
    });
    for id in 0..8u64 {
        let mass = if id % 2 == 0 { 1.0f32 } else { 0.01 };
        let profile = vec![mass; 64];
        let snap = KvSnapshot::new(tags::FULL, vec![(id as u8) + 1; payload]);
        pager.put(id, &snap, Some(&profile)).expect("park");
    }
    for id in [0u64, 2, 4, 6] {
        let snap = pager.take(id).expect("resume");
        assert_eq!(snap.payload(), vec![(id as u8) + 1; payload], "resume intact");
    }
    for id in [1u64, 3, 5, 7] {
        assert!(pager.discard(id), "cancelled sequence was parked");
    }
    assert!(pager.is_empty());
    let stats = pager.stats();
    drop(pager);
    let _ = std::fs::remove_dir_all(dir);
    stats
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_perf_paging",
        "§Perf: attention-aware multi-tier KV paging — storm bit-identity, prefetch overlap, eviction A/B",
    );
    let fast = args.get_flag("fast");
    let mut results = Json::obj();

    // ---- 1. Preemption storm: six policies, bit-identity ---------------
    let (long_n, storms) = if fast { (150usize, 1usize) } else { (1200, 3) };
    let mut t1 = Table::new(
        "paging storm (disk-backed preemption vs never-preempted oracle)",
        &["policy", "preempt/restore", "prefetch h/m", "io stall (ms)", "wall (s)", "identical"],
    );
    for (name, mk) in policies() {
        let c = run_storm(name, mk, long_n, storms)?;
        t1.row(&[
            name.to_string(),
            format!("{}/{}", c.preemptions, c.restores),
            format!("{}/{}", c.prefetch_hits, c.prefetch_misses),
            format!("{:.3}", c.io_stall_s * 1e3),
            format!("{:.2}", c.wall_s),
            "yes".to_string(), // asserted inside run_storm
        ]);
        let key = |m: &str| format!("storm_{name}_{m}");
        results.set(&key("preemptions"), Json::Num(c.preemptions as f64));
        results.set(&key("restores"), Json::Num(c.restores as f64));
        results.set(&key("prefetch_hits"), Json::Num(c.prefetch_hits as f64));
        results.set(&key("prefetch_misses"), Json::Num(c.prefetch_misses as f64));
        results.set(&key("io_stall_ms"), Json::Num(c.io_stall_s * 1e3));
        results.set(&key("wall_s"), Json::Num(c.wall_s));
        results.set(&key("bit_identical"), Json::Bool(true));
    }
    t1.print();
    println!("acceptance: all six policies bit-identical under the storm (asserted)");

    // ---- 2. Prefetch overlap: hidden restore stall ----------------------
    let (n_seqs, payload, reps) = if fast { (4u64, 128 * 1024, 1) } else { (8, 512 * 1024, 3) };
    let dir2 = tmp("overlap");
    // Warmup populates the page cache so both modes read warm files.
    run_overlap(&dir2, n_seqs, payload, false, 0.0);
    let (mut sync_stall, mut sync_wall) = (0.0f64, 0.0f64);
    let (mut ov_stall, mut ov_wall) = (0.0f64, 0.0f64);
    let (mut hits, mut misses) = (0u64, 0u64);
    let mut compute_total = 0.0f64;
    for _ in 0..reps {
        let sync = run_overlap(&dir2, n_seqs, payload, false, 0.0);
        // The stand-in decode round is sized at 2x the measured sync
        // stall, so a completed prefetch has genuinely been overlapped
        // with compute the worker would have done anyway.
        let compute_s = (2.0 * sync.io_stall_s).max(2e-3);
        compute_total += compute_s;
        let ov = run_overlap(&dir2, n_seqs, payload, true, compute_s);
        sync_stall += sync.io_stall_s;
        sync_wall += sync.take_wall_s;
        ov_stall += ov.io_stall_s;
        ov_wall += ov.take_wall_s;
        hits += ov.hits;
        misses += ov.misses;
    }
    let hidden = if sync_stall > 0.0 { 1.0 - ov_stall / sync_stall } else { 0.0 };
    let mut t2 = Table::new(
        "prefetch overlap (pager-level restore of a spilled working set)",
        &["mode", "io stall (ms)", "take wall (ms)", "prefetch h/m"],
    );
    t2.row(&[
        "sync".to_string(),
        format!("{:.3}", sync_stall * 1e3),
        format!("{:.3}", sync_wall * 1e3),
        "-".to_string(),
    ]);
    t2.row(&[
        "prefetch".to_string(),
        format!("{:.3}", ov_stall * 1e3),
        format!("{:.3}", ov_wall * 1e3),
        format!("{hits}/{misses}"),
    ]);
    t2.print();
    println!(
        "prefetch hides {:.1}% of the synchronous restore stall \
         (acceptance: >= 70%{})",
        hidden * 100.0,
        if fast { "; report-only under --fast" } else { "" },
    );
    if !fast {
        assert!(
            hidden >= 0.70,
            "prefetch must hide >= 70% of sync restore stall, hid {:.1}%",
            hidden * 100.0
        );
    }
    results.set("overlap_sync_io_stall_ms", Json::Num(sync_stall * 1e3));
    results.set("overlap_prefetch_io_stall_ms", Json::Num(ov_stall * 1e3));
    results.set("overlap_hidden_frac", Json::Num(hidden));
    results.set("overlap_compute_ms", Json::Num(compute_total * 1e3));
    results.set("overlap_sync_take_wall_ms", Json::Num(sync_wall * 1e3));
    results.set("overlap_prefetch_take_wall_ms", Json::Num(ov_wall * 1e3));
    results.set("overlap_prefetch_hits", Json::Num(hits as f64));
    results.set("overlap_prefetch_misses", Json::Num(misses as f64));

    // ---- 3. Eviction scoring A/B: restore volume at equal budgets -------
    let payload3 = if fast { 16 * 1024 } else { 64 * 1024 };
    let dir3 = tmp("scoring");
    let attn = run_scoring(&dir3, EvictionScoring::Attention, payload3);
    let age = run_scoring(&dir3, EvictionScoring::Age, payload3);
    let mut t3 = Table::new(
        "eviction scoring A/B (equal warm budgets, half the set resumed)",
        &["scoring", "promote bytes", "promote blocks", "spill bytes"],
    );
    for (label, s) in [("attention", &attn), ("age", &age)] {
        t3.row(&[
            label.to_string(),
            s.promote_bytes.to_string(),
            s.block_promotes.to_string(),
            s.spill_bytes.to_string(),
        ]);
    }
    t3.print();
    let saved = if age.promote_bytes > 0 {
        1.0 - attn.promote_bytes as f64 / age.promote_bytes as f64
    } else {
        0.0
    };
    println!(
        "attention-aware eviction restores {:.1}% less than age-only at equal budgets \
         (acceptance: strictly less)",
        saved * 100.0
    );
    assert!(
        attn.promote_bytes < age.promote_bytes,
        "attention scoring must beat age-only on restore volume: {} vs {}",
        attn.promote_bytes,
        age.promote_bytes
    );
    results.set("evict_attention_promote_bytes", Json::Num(attn.promote_bytes as f64));
    results.set("evict_age_promote_bytes", Json::Num(age.promote_bytes as f64));
    results.set("evict_attention_block_promotes", Json::Num(attn.block_promotes as f64));
    results.set("evict_age_block_promotes", Json::Num(age.block_promotes as f64));
    results.set("evict_restore_saved_frac", Json::Num(saved));

    t1.save_csv(&cskv::runs_dir().join("perf_paging.csv"))?;
    let root = Json::from_pairs(vec![
        ("bench", Json::Str("bench_perf_paging".to_string())),
        (
            "git_rev",
            Json::Str(git_rev().unwrap_or_else(|| "unknown".to_string())),
        ),
        ("results", results),
    ]);
    let json_path = cskv::runs_dir().join("BENCH_perf_paging.json");
    std::fs::write(&json_path, root.to_string_pretty())?;
    println!("wrote {}", json_path.display());
    println!("done; see EXPERIMENTS.md §Perf for the recorded numbers");
    Ok(())
}
