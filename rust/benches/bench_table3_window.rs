//! Table 3 — window-size ablation at 80% compression: m ∈ {2..256}
//! (scaled from the paper's {2..4096} to our 512-token context).
//!
//! Run: `cargo bench --bench bench_table3_window [-- --fast]`

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::eval::experiments::{build_sets, eval_cell, factors_for, Env, Method, FT_STEPS};
use cskv::eval::Suite;
use cskv::finetune::recon::QatMode;
use cskv::kvcache::QuantMode;
use cskv::util::bench::print_bench_header;
use cskv::util::cli::Args;
use cskv::util::table::{acc, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header("bench_table3_window", "CSKV paper Table 3 (window size)");
    let n = if args.get_flag("fast") { 8 } else { args.get_usize("samples", 25) };
    let seed = args.get_u64("seed", 44);
    let env = Env::load_default()?;

    let columns = Suite::ablation_columns();
    let sets = build_sets(&env, &columns, n, seed);
    let avg_of = |method: &Method| -> f64 {
        columns
            .iter()
            .zip(&sets)
            .map(|((_, suite), set)| eval_cell(&env, set, suite, method).agreement())
            .sum::<f64>()
            / columns.len() as f64
    };

    let mut t = Table::new(
        "Table 3: window size at 80% compression (LongEval avg)",
        &["C.Ratio", "Window Size", "Avg.Acc"],
    );
    t.row(&["0%".into(), "-".into(), acc(avg_of(&Method::Full))]);

    let plan = KvCompressionPlan::uniform(0.8);
    let f = factors_for(&env, plan, InitMethod::asvd_default(), FT_STEPS, QatMode::Off);
    let windows: Vec<usize> = args.get_list_usize("windows", &[2, 4, 8, 16, 32, 64, 128, 256]);
    for w in windows {
        let m = Method::Cskv {
            factors: std::sync::Arc::clone(&f),
            window: w,
            quant: QuantMode::None,
        };
        t.row(&["80%".into(), w.to_string(), acc(avg_of(&m))]);
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("table3.csv"))?;
    println!("saved runs/table3.csv");
    Ok(())
}
