//! Table 2 — initialization ablation: {Random, SVD, ASVD (+Oracle ext.)}
//! × ratio {50,60,70,80}% → LongEval average accuracy.
//!
//! Run: `cargo bench --bench bench_table2_init [-- --fast]`

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::eval::experiments::{build_sets, eval_cell, factors_for, Env, Method, FT_STEPS};
use cskv::eval::Suite;
use cskv::finetune::recon::QatMode;
use cskv::kvcache::QuantMode;
use cskv::util::bench::print_bench_header;
use cskv::util::cli::Args;
use cskv::util::table::{acc, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header("bench_table2_init", "CSKV paper Table 2 (init methods)");
    let n = if args.get_flag("fast") { 8 } else { args.get_usize("samples", 25) };
    let seed = args.get_u64("seed", 43);
    let env = Env::load_default()?;

    let columns = Suite::ablation_columns();
    let sets = build_sets(&env, &columns, n, seed);
    let avg_of = |method: &Method| -> f64 {
        let mut s = 0.0;
        for ((_, suite), set) in columns.iter().zip(&sets) {
            s += eval_cell(&env, set, suite, method).agreement();
        }
        s / columns.len() as f64
    };

    let mut t = Table::new("Table 2: init method ablation (LongEval avg)", &[
        "C.Ratio", "Init.Method", "Avg.Acc",
    ]);
    t.row(&["0%".into(), "-".into(), acc(avg_of(&Method::Full))]);

    let inits: &[(&str, InitMethod)] = &[
        ("Random", InitMethod::Random),
        ("SVD", InitMethod::Svd),
        ("ASVD", InitMethod::asvd_default()),
        ("Oracle (ext.)", InitMethod::Oracle),
    ];
    for ratio in [0.5f64, 0.6, 0.7, 0.8] {
        let plan = KvCompressionPlan::uniform(ratio);
        for (label, init) in inits {
            let f = factors_for(&env, plan, *init, FT_STEPS, QatMode::Off);
            let m = Method::Cskv {
                factors: f,
                window: 32,
                quant: QuantMode::None,
            };
            t.row(&[
                format!("{}%", (ratio * 100.0) as u32),
                label.to_string(),
                acc(avg_of(&m)),
            ]);
        }
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("table2.csv"))?;
    println!("saved runs/table2.csv");
    Ok(())
}
