//! §Perf — the batched serving data plane: fused vs sequential rounds.
//!
//! Four measurements:
//! 1. micro: one GEMM-batched decode round (`decode_batch`) vs B
//!    per-sequence `decode_next` calls at batch 8, ctx 256 — the
//!    headline: the fused round streams each weight once instead of once
//!    per sequence (target ≥ 1.5× aggregate decode tokens/s, full cache).
//! 2. micro: one fused admission prefill (`prefill_batch`) vs B
//!    sequential prefills at batch 8.
//! 3. serving: end-to-end coordinator runs at queue depths {1, 4, 8} ×
//!    {full, cskv80} × {fused, sequential} — aggregate tokens/s and p50
//!    TTFT (fused admission prefill makes TTFT grow sublinearly with
//!    depth).
//! 4. pool reuse A/B: `parallel_chunks` on the persistent pool vs the
//!    scoped-spawn baseline (`parallel_chunks_scoped`), many small
//!    regions per iteration — the ROADMAP "NUMA / pool reuse" item.
//!
//! Like `bench_perf_prefill`, the model comes from `ModelWeights::init`
//! so the bench runs anywhere (CI included; no pretrained weights
//! needed). Results land in `runs/BENCH_perf_serving.json`.
//!
//! Run: `cargo bench --bench bench_perf_serving [-- --fast]`

use std::sync::Arc;

use cskv::compress::{KvCompressionPlan, LayerFactors, LowRankFactors, ModelFactors};
use cskv::coordinator::backend::{decode_batch, prefill_batch, BatchScratch};
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{Coordinator, CoordinatorConfig, RustSequenceBackend, SequenceBackend};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::engine::Engine;
use cskv::model::{ModelConfig, ModelWeights};
use cskv::tensor::Mat;
use cskv::util::bench::{git_rev, print_bench_header, Bencher};
use cskv::util::cli::Args;
use cskv::util::json::Json;
use cskv::util::prng::Pcg64;
use cskv::util::table::Table;
use cskv::util::threadpool::{parallel_chunks, parallel_chunks_scoped};

fn factors_for(cfg: &ModelConfig) -> Arc<ModelFactors> {
    let plan = KvCompressionPlan::uniform(0.8);
    let (rk, rv) = (plan.rank_k(cfg.d_model), plan.rank_v(cfg.d_model));
    let mut rng = Pcg64::new(11);
    let layers = (0..cfg.n_layers)
        .map(|_| LayerFactors {
            k: LowRankFactors::new(
                Mat::randn(cfg.d_model, rk, 0.2, &mut rng),
                Mat::randn(rk, cfg.d_model, 0.2, &mut rng),
            ),
            v: LowRankFactors::new(
                Mat::randn(cfg.d_model, rv, 0.2, &mut rng),
                Mat::randn(rv, cfg.d_model, 0.2, &mut rng),
            ),
        })
        .collect();
    Arc::new(ModelFactors {
        layers,
        provenance: "bench-serving".into(),
    })
}

fn mk_policy(
    use_cskv: bool,
    cfg: &ModelConfig,
    factors: &Arc<ModelFactors>,
) -> Box<dyn KvCachePolicy> {
    if use_cskv {
        Box::new(CskvCache::new(
            Arc::clone(factors),
            cfg.d_model,
            CskvConfig { window: 32, quant: QuantMode::None },
        ))
    } else {
        Box::new(FullCache::new(cfg.n_layers, cfg.d_model))
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_perf_serving",
        "§Perf: fused multi-sequence prefill + GEMM-batched decode rounds vs sequential",
    );
    let fast = args.get_flag("fast");
    let cfg = ModelConfig::tiny();
    let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 42)));
    let factors = factors_for(&cfg);
    let mut results = Json::obj();

    // ---- 1. decode rounds: fused vs sequential, batch 8, ctx 256 -------
    // Both arms run the identical fixed number of rounds from the same
    // starting context so position-dependent attention cost cancels.
    let (batch_n, ctx) = (8usize, 256usize);
    let rounds = if fast { 6 } else { 48 };
    let mut b = if fast { Bencher::fast() } else { Bencher::new() };
    let mut br = Bencher::new();
    br.warmup_iters = 2;
    br.min_iters = rounds;
    br.max_iters = rounds;
    for (label, use_cskv) in [("full", false), ("cskv80", true)] {
        let mk_backends = |seed: u64| -> anyhow::Result<Vec<Box<dyn SequenceBackend>>> {
            let mut rng = Pcg64::new(seed);
            let mut v: Vec<Box<dyn SequenceBackend>> = Vec::with_capacity(batch_n);
            for _ in 0..batch_n {
                let mut be = Box::new(RustSequenceBackend::new(
                    engine.clone(),
                    mk_policy(use_cskv, &cfg, &factors),
                ));
                let prompt: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
                be.prefill(&prompt)?;
                v.push(be);
            }
            Ok(v)
        };
        let mut fused_set = mk_backends(5)?;
        let mut scratch = BatchScratch::default();
        let rf = br.time(&format!("decode round fused {label} B={batch_n} ctx={ctx}"), || {
            let mut bs: Vec<&mut dyn SequenceBackend> =
                fused_set.iter_mut().map(|x| x.as_mut()).collect();
            for r in decode_batch(&mut bs, &mut scratch) {
                r.unwrap();
            }
        });
        let fused_ns = rf.samples.percentile(50.0) * 1e9;
        let mut seq_set = mk_backends(5)?;
        let rs = br.time(
            &format!("decode round sequential {label} B={batch_n} ctx={ctx}"),
            || {
                for be in seq_set.iter_mut() {
                    be.decode_next().unwrap();
                }
            },
        );
        let seq_ns = rs.samples.percentile(50.0) * 1e9;
        let speedup = seq_ns / fused_ns;
        println!(
            "speedup {label} B={batch_n} ctx={ctx}: fused decode round {speedup:.2}x vs \
             sequential (acceptance target ≥1.50x for full)",
        );
        results.set(&format!("decode_round_fused_{label}_ns"), Json::Num(fused_ns));
        results.set(&format!("decode_round_sequential_{label}_ns"), Json::Num(seq_ns));
        results.set(&format!("decode_round_speedup_{label}"), Json::Num(speedup));
    }

    // ---- 2. admission prefill: fused vs sequential, batch 8 ------------
    {
        let pctx = if fast { 64 } else { 128 };
        let prompts: Vec<Vec<usize>> = {
            let mut rng = Pcg64::new(7);
            (0..batch_n)
                .map(|_| (0..pctx).map(|_| rng.range(16, 250)).collect())
                .collect()
        };
        let prompt_refs: Vec<&[usize]> = prompts.iter().map(|p| p.as_slice()).collect();
        let mut scratch = BatchScratch::default();
        let rf = b.time(&format!("prefill round fused B={batch_n} ctx={pctx}"), || {
            let mut backends: Vec<Box<dyn SequenceBackend>> = (0..batch_n)
                .map(|_| {
                    Box::new(RustSequenceBackend::new(
                        engine.clone(),
                        mk_policy(false, &cfg, &factors),
                    )) as Box<dyn SequenceBackend>
                })
                .collect();
            let mut bs: Vec<&mut dyn SequenceBackend> =
                backends.iter_mut().map(|x| x.as_mut()).collect();
            for r in prefill_batch(&mut bs, &prompt_refs, &mut scratch) {
                r.unwrap();
            }
        });
        let fused_ns = rf.samples.percentile(50.0) * 1e9;
        let rs = b.time(&format!("prefill round sequential B={batch_n} ctx={pctx}"), || {
            for p in &prompt_refs {
                let mut be = RustSequenceBackend::new(
                    engine.clone(),
                    mk_policy(false, &cfg, &factors),
                );
                be.prefill(p).unwrap();
            }
        });
        let seq_ns = rs.samples.percentile(50.0) * 1e9;
        println!(
            "speedup prefill B={batch_n} ctx={pctx}: fused {:.2}x vs sequential",
            seq_ns / fused_ns
        );
        results.set("prefill_round_fused_ns", Json::Num(fused_ns));
        results.set("prefill_round_sequential_ns", Json::Num(seq_ns));
    }

    // ---- 3. end-to-end serving: depth × policy × data plane ------------
    let mut t = Table::new(
        "serving (aggregate over full generation; TTFT p50 in seconds)",
        &["depth", "policy", "plane", "tok/s", "ttft p50 (s)", "max conc"],
    );
    let sctx = if fast { 96 } else { 192 };
    let n_new = if fast { 8 } else { 16 };
    for depth in [1usize, 4, 8] {
        for (label, use_cskv) in [("full", false), ("cskv80", true)] {
            for (plane, fused) in [("fused", true), ("sequential", false)] {
                let engine2 = engine.clone();
                let f2 = Arc::clone(&factors);
                let cfg2 = cfg.clone();
                let setup: Setup = Box::new(move || {
                    let factory: BackendFactory = Box::new(move || {
                        Ok(Box::new(RustSequenceBackend::new(
                            engine2.clone(),
                            mk_policy(use_cskv, &cfg2, &f2),
                        )))
                    });
                    Ok(factory)
                });
                let coord = Coordinator::start(
                    setup,
                    CoordinatorConfig {
                        max_batch: depth,
                        fused,
                        ..Default::default()
                    },
                );
                let n_req = depth * 2;
                let mut rng = Pcg64::new(17);
                let rxs: Vec<_> = (0..n_req)
                    .map(|_| {
                        let prompt: Vec<usize> =
                            (0..sctx).map(|_| rng.range(16, 250)).collect();
                        coord.submit(prompt, n_new)
                    })
                    .collect();
                for rx in rxs {
                    rx.recv().unwrap();
                }
                let snap = coord.shutdown();
                let tok_s = snap.throughput_tok_s();
                let ttft_p50 = snap.ttft_s.percentile(50.0);
                t.row(&[
                    depth.to_string(),
                    label.to_string(),
                    plane.to_string(),
                    format!("{tok_s:.1}"),
                    format!("{ttft_p50:.4}"),
                    snap.active_peak.to_string(),
                ]);
                results.set(
                    &format!("serving_q{depth}_{label}_{plane}_tok_s"),
                    Json::Num(tok_s),
                );
                results.set(
                    &format!("serving_q{depth}_{label}_{plane}_ttft_p50_s"),
                    Json::Num(ttft_p50),
                );
            }
        }
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("perf_serving.csv"))?;

    // ---- 3b. decode-side threading A/B: batched GEMV column split ------
    // The ROADMAP decode-threading item: at large B × d_ff the fused
    // round's down-projection pays for a column split across the pool.
    {
        use cskv::tensor::matmul::{matvec_t_batch_into, par_matvec_t_batch_into};
        let (d_in, d_out, bsz) = (cfg.d_model, cfg.d_ff.max(4 * cfg.d_model), 16usize);
        let mut rng = Pcg64::new(23);
        let a = Mat::randn(d_in, d_out, 0.2, &mut rng);
        let xs = Mat::randn(bsz, d_in, 1.0, &mut rng);
        let mut ys = Mat::zeros(bsz, d_out);
        let r1 = b.time(&format!("batched GEMV serial B={bsz} {d_in}x{d_out}"), || {
            matvec_t_batch_into(&a, &xs, &mut ys);
        });
        let serial_ns = r1.samples.percentile(50.0) * 1e9;
        for threads in [2usize, 4] {
            let mut yt = Mat::zeros(bsz, d_out);
            let rt = b.time(
                &format!("batched GEMV col-split w={threads} B={bsz} {d_in}x{d_out}"),
                || {
                    par_matvec_t_batch_into(&a, &xs, &mut yt, threads);
                },
            );
            assert_eq!(yt.data, ys.data, "column split must be bit-identical");
            let par_ns = rt.samples.percentile(50.0) * 1e9;
            println!(
                "decode GEMV col-split w={threads}: {:.2}x vs serial",
                serial_ns / par_ns
            );
            results.set(
                &format!("batch_gemv_par_w{threads}_ns"),
                Json::Num(par_ns),
            );
            results.set(
                &format!("batch_gemv_speedup_w{threads}"),
                Json::Num(serial_ns / par_ns),
            );
        }
        results.set("batch_gemv_serial_ns", Json::Num(serial_ns));
    }

    // ---- 4. pool reuse A/B ---------------------------------------------
    {
        let n_rows = 64usize;
        let width = 4usize;
        let regions = if fast { 50 } else { 400 };
        let buf = vec![0.0f32; n_rows * 256];
        let rp = b.time(&format!("{regions} small regions, pooled pool w={width}"), || {
            for _ in 0..regions {
                parallel_chunks(n_rows, width, |lo, hi| {
                    for r in lo..hi {
                        let row = &buf[r * 256..(r + 1) * 256];
                        let s: f32 = row.iter().sum();
                        std::hint::black_box(s);
                    }
                });
            }
        });
        let pooled_ns = rp.samples.percentile(50.0) * 1e9;
        let rs = b.time(&format!("{regions} small regions, scoped spawn w={width}"), || {
            for _ in 0..regions {
                parallel_chunks_scoped(n_rows, width, |lo, hi| {
                    for r in lo..hi {
                        let row = &buf[r * 256..(r + 1) * 256];
                        let s: f32 = row.iter().sum();
                        std::hint::black_box(s);
                    }
                });
            }
        });
        let scoped_ns = rs.samples.percentile(50.0) * 1e9;
        println!(
            "pool reuse A/B: persistent pool {:.2}x vs per-call scoped spawn",
            scoped_ns / pooled_ns
        );
        results.set("pool_small_regions_pooled_ns", Json::Num(pooled_ns));
        results.set("pool_small_regions_scoped_ns", Json::Num(scoped_ns));
        results.set("pool_reuse_speedup", Json::Num(scoped_ns / pooled_ns));
    }

    // Machine-readable trajectory.
    let root = Json::from_pairs(vec![
        ("bench", Json::Str("bench_perf_serving".to_string())),
        (
            "git_rev",
            Json::Str(git_rev().unwrap_or_else(|| "unknown".to_string())),
        ),
        ("results", results),
    ]);
    let json_path = cskv::runs_dir().join("BENCH_perf_serving.json");
    std::fs::write(&json_path, root.to_string_pretty())?;
    println!("wrote {}", json_path.display());
    println!("done; see EXPERIMENTS.md §Perf for the recorded numbers");
    Ok(())
}
