//! §Perf — long-context prefill latency: the second hot path, after
//! `bench_perf_decode` covered decode.
//!
//! Four measurements:
//! 1. GEMM inner loop A/B: the dense blocked kernel with vs without the
//!    removed `aip == 0.0` per-element branch (the satellite's measured
//!    before/after record), plus SIMD-dispatch vs scalar-oracle rows on
//!    the same shapes.
//! 1b. `A·Bᵀ` depth blocking A/B: the `KC`-blocked score kernel vs the
//!    pre-PR full-length-dot baseline (kept bench-local), at a depth
//!    below `KC` (blocking is a no-op) and one well above it (where the
//!    B-panel re-streaming pays), with scalar-oracle rows alongside.
//! 2. prefill: streaming tiled parallel prefill ([`Engine::prefill`]) at
//!    1/2/4/8 worker threads vs the pre-PR serial path (kept verbatim as
//!    [`Engine::prefill_reference`]), across context lengths — the
//!    headline rows print the speedup ratios directly:
//!    * gate A: ≥ 3× at ctx = 509 with 8 threads vs the serial reference
//!      (needs the cores to exist — the ratio is measured, not assumed),
//!    * gate B: ≥ 1.3× at 1 thread from tiling / triangle-skipping /
//!      RoPE-caching / allocation-thrift alone.
//! 3. policy-attached prefill at ctx = 509 (full cache and CSKV 80%),
//!    confirming the policy seam doesn't erase the win.
//!
//! No trained weights required — prefill cost is value-independent, so
//! the bench runs from `ModelWeights::init` anywhere (CI included).
//!
//! Results are also written to `runs/BENCH_perf_prefill.json`
//! (name → median ns + git rev) so the perf trajectory tooling picks
//! this bench up alongside `runs/BENCH_perf_decode.json`.
//!
//! Run: `cargo bench --bench bench_perf_prefill [-- --fast --threads N]`

use std::sync::Arc;

use cskv::compress::{KvCompressionPlan, LayerFactors, LowRankFactors, ModelFactors};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::engine::{Engine, PrefillScratch};
use cskv::model::{ModelConfig, ModelWeights};
use cskv::tensor::matmul::{
    axpy_row, dot, matmul_into, matmul_into_scalar, matmul_nt_into, matmul_nt_into_scalar, KC,
};
use cskv::tensor::Mat;
use cskv::util::bench::{black_box, print_bench_header, Bencher};
use cskv::util::cli::Args;
use cskv::util::prng::Pcg64;
use cskv::util::threadpool::ThreadPool;

/// The pre-PR `matmul_nt_into` — one full-length dot per output element,
/// no `KC` depth blocking — kept here (and only here) as the baseline for
/// the depth-blocking A/B. Uses the same dispatched [`dot`] primitive, so
/// the row isolates blocking from SIMD.
fn matmul_nt_into_unblocked(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let k = a.cols;
    let n = b.rows;
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (j, cj) in crow.iter_mut().enumerate() {
            *cj = dot(arow, &b.data[j * k..(j + 1) * k]);
        }
    }
}

/// The pre-PR `matmul_into` inner loop, branch included — kept here (and
/// only here) as the A/B baseline for the removed `aip == 0.0` skip.
fn matmul_into_branchy(a: &Mat, b: &Mat, c: &mut Mat) {
    const MC: usize = 64;
    const KC: usize = 256;
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    c.data.fill(0.0);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MC).min(m);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            for i in i0..i1 {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for p in k0..k1 {
                    let aip = arow[p];
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &b.data[p * n..(p + 1) * n];
                    axpy_row(crow, aip, brow);
                }
            }
            k0 = k1;
        }
        i0 = i1;
    }
}

/// Random low-rank factors for the CSKV policy row (prefill cost is
/// value-independent, so random factors measure the same work as trained
/// ones).
fn random_factors(cfg: &ModelConfig, rank: usize) -> Arc<ModelFactors> {
    let d = cfg.d_model;
    let mut rng = Pcg64::new(11);
    let mut mk = move || {
        LowRankFactors::new(
            Mat::randn(d, rank, 0.2, &mut rng),
            Mat::randn(rank, d, 0.2, &mut rng),
        )
    };
    Arc::new(ModelFactors {
        layers: (0..cfg.n_layers)
            .map(|_| LayerFactors { k: mk(), v: mk() })
            .collect(),
        provenance: "bench-random".into(),
    })
}

fn engine_with_threads(cfg: &ModelConfig, threads: usize) -> Engine {
    // Same init seed ⇒ identical weights at every width; only the knob
    // differs.
    let c = cfg.clone().with_threads(threads);
    Engine::new(Arc::new(ModelWeights::init(&c, 42)))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_perf_prefill",
        "§Perf: streaming tiled parallel prefill vs the pre-PR serial path",
    );
    let fast = args.get_flag("fast");
    let max_threads = args.get_usize("threads", 8);
    let cores = ThreadPool::available_parallelism();
    println!("(8-thread rows are meaningful only with ≥8 cores; this host has {cores})");
    let mut b = if fast { Bencher::fast() } else { Bencher::new() };
    let cfg = ModelConfig::tiny();

    // ---- 1. GEMM inner-loop branch A/B (dense operands) -----------------
    {
        let mut rng = Pcg64::new(3);
        // The two dense shapes prefill actually runs: QKV projection and
        // the MLP up-projection at ctx 509.
        for (m, k, n, label) in [(509usize, 128usize, 128usize, "qkv-proj"), (509, 128, 512, "mlp-up")] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let bm = Mat::randn(k, n, 1.0, &mut rng);
            let mut c = Mat::zeros(m, n);
            b.time(&format!("gemm {label} {m}x{k}x{n} branchless"), || {
                matmul_into(&a, &bm, &mut c);
                black_box(c.data[0]);
            });
            b.time(&format!("gemm {label} {m}x{k}x{n} branchy(pre-PR)"), || {
                matmul_into_branchy(&a, &bm, &mut c);
                black_box(c.data[0]);
            });
            b.time(&format!("gemm {label} {m}x{k}x{n} scalar-oracle"), || {
                matmul_into_scalar(&a, &bm, &mut c);
                black_box(c.data[0]);
            });
        }
        let med = |b: &Bencher, name: &str| -> Option<f64> {
            b.results()
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.samples.percentile(50.0))
        };
        for (m, k, n, label) in [(509usize, 128usize, 128usize, "qkv-proj"), (509, 128, 512, "mlp-up")] {
            if let (Some(new), Some(old)) = (
                med(&b, &format!("gemm {label} {m}x{k}x{n} branchless")),
                med(&b, &format!("gemm {label} {m}x{k}x{n} branchy(pre-PR)")),
            ) {
                if new > 0.0 {
                    println!("gemm branch removal {label}: {:.3}x vs pre-PR branchy", old / new);
                }
            }
            if let (Some(dispatch), Some(scalar)) = (
                med(&b, &format!("gemm {label} {m}x{k}x{n} branchless")),
                med(&b, &format!("gemm {label} {m}x{k}x{n} scalar-oracle")),
            ) {
                if dispatch > 0.0 {
                    println!(
                        "gemm simd dispatch {label}: {:.3}x vs scalar oracle (simd feature {})",
                        scalar / dispatch,
                        if cfg!(feature = "simd") { "on" } else { "off" },
                    );
                }
            }
        }
    }

    // ---- 1b. A·Bᵀ depth-blocking + SIMD A/B -----------------------------
    {
        let mut rng = Pcg64::new(7);
        // Two depths around the KC boundary: the score panel prefill runs
        // (k = d_model, below KC ⇒ blocking is a structural no-op) and a
        // long-depth panel (k = 4·KC) where re-streaming the B panel per
        // depth block is the point.
        for (m, n, k) in [(509usize, 509usize, 128usize), (256, 509, 4 * KC)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let bm = Mat::randn(n, k, 1.0, &mut rng);
            let mut c = Mat::zeros(m, n);
            b.time(&format!("gemm-nt {m}x{n}x{k} blocked"), || {
                matmul_nt_into(&a, &bm, &mut c);
                black_box(c.data[0]);
            });
            b.time(&format!("gemm-nt {m}x{n}x{k} unblocked(pre-PR)"), || {
                matmul_nt_into_unblocked(&a, &bm, &mut c);
                black_box(c.data[0]);
            });
            b.time(&format!("gemm-nt {m}x{n}x{k} blocked scalar-oracle"), || {
                matmul_nt_into_scalar(&a, &bm, &mut c);
                black_box(c.data[0]);
            });
        }
        let med = |b: &Bencher, name: &str| -> Option<f64> {
            b.results()
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.samples.percentile(50.0))
        };
        for (m, n, k) in [(509usize, 509usize, 128usize), (256, 509, 4 * KC)] {
            if let (Some(blocked), Some(unblocked), Some(scalar)) = (
                med(&b, &format!("gemm-nt {m}x{n}x{k} blocked")),
                med(&b, &format!("gemm-nt {m}x{n}x{k} unblocked(pre-PR)")),
                med(&b, &format!("gemm-nt {m}x{n}x{k} blocked scalar-oracle")),
            ) {
                if blocked > 0.0 {
                    println!(
                        "gemm-nt k={k}: KC-blocking {:.3}x vs unblocked, simd {:.3}x vs scalar",
                        unblocked / blocked,
                        scalar / blocked,
                    );
                }
            }
        }
    }

    // ---- 2. prefill: serial reference vs streaming at 1..8 threads ------
    let thread_grid: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&t| t <= max_threads)
        .collect();
    let mut rng = Pcg64::new(5);
    for ctx in [128usize, 256, 509] {
        let prompt: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
        let reference = engine_with_threads(&cfg, 1);
        b.time(&format!("prefill serial-reference ctx={ctx}"), || {
            black_box(reference.prefill_reference(&prompt, None).logits.rows);
        });
        for &threads in &thread_grid {
            let engine = engine_with_threads(&cfg, threads);
            let mut scratch = PrefillScratch::new();
            b.time(&format!("prefill streaming t={threads} ctx={ctx}"), || {
                black_box(engine.prefill_with(&prompt, None, &mut scratch).logits.rows);
            });
        }
    }

    // Headline ratios (median-based) — the two acceptance gates.
    {
        let med = |name: &str| -> Option<f64> {
            b.results()
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.samples.percentile(50.0))
        };
        for ctx in [128usize, 256, 509] {
            if let Some(reference) = med(&format!("prefill serial-reference ctx={ctx}")) {
                for &threads in &thread_grid {
                    if let Some(new) = med(&format!("prefill streaming t={threads} ctx={ctx}")) {
                        if new > 0.0 {
                            println!(
                                "speedup ctx={ctx} t={threads}: streaming {:.2}x vs serial reference{}",
                                reference / new,
                                match (ctx, threads) {
                                    (509, 8) => "   <-- gate A (>=3x with 8 cores)",
                                    (509, 1) => "   <-- gate B (>=1.3x serial-only)",
                                    _ => "",
                                }
                            );
                        }
                    }
                }
            }
        }
    }

    // ---- 3. policy-attached prefill at ctx = 509 ------------------------
    {
        let ctx = 509usize;
        let prompt: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
        let rank = KvCompressionPlan::uniform(0.8).rank_k(cfg.d_model);
        let factors = random_factors(&cfg, rank);
        let top = *thread_grid.last().unwrap_or(&1);
        let engine = engine_with_threads(&cfg, top);
        let reference = engine_with_threads(&cfg, 1);
        let variants: [(&str, Option<QuantMode>); 2] = [("full", None), ("cskv80", Some(QuantMode::None))];
        let mk_policy = |quant: Option<QuantMode>| -> Box<dyn KvCachePolicy> {
            match quant {
                None => Box::new(FullCache::new(cfg.n_layers, cfg.d_model)),
                Some(q) => Box::new(CskvCache::new(
                    Arc::clone(&factors),
                    cfg.d_model,
                    CskvConfig { window: 32, quant: q },
                )),
            }
        };
        for (label, quant) in variants {
            let mut scratch = PrefillScratch::new();
            // Fresh policy per iteration: ingest state must not accumulate
            // across timed runs.
            b.time(&format!("prefill+policy {label} streaming t={top} ctx={ctx}"), || {
                let mut p = mk_policy(quant);
                black_box(engine.prefill_with(&prompt, Some(p.as_mut()), &mut scratch).logits.rows);
            });
            b.time(&format!("prefill+policy {label} serial-reference ctx={ctx}"), || {
                let mut p = mk_policy(quant);
                black_box(reference.prefill_reference(&prompt, Some(p.as_mut())).logits.rows);
            });
        }
    }

    // Machine-readable trajectory: name → median ns (+ git rev).
    let json_path = cskv::runs_dir().join("BENCH_perf_prefill.json");
    b.write_json("bench_perf_prefill", &json_path)?;
    println!("wrote {}", json_path.display());
    println!("done; see EXPERIMENTS.md §Perf for the recorded numbers");
    Ok(())
}
