//! §Perf — decode latency/throughput and serving concurrency.
//!
//! Five measurements:
//! 1. micro: per-token decode latency vs context length, full vs CSKV
//!    (fp32 and int4) with the engine's persistent incremental
//!    [`DecodeState`], plus "rematerialize" rows (at every context) that
//!    rebuild the views from scratch every step — exactly what the
//!    pre-incremental decode path did, so one run shows the
//!    O(context) → O(window + rank) speedup directly.
//! 1b. fused int4 attention kernel A/B: scoring/weighting straight off
//!    packed [`QuantizedBlock`] groups vs dequantizing them into an f32
//!    scratch and running the plain GEMV kernels — the win the fused
//!    decode path banks every step.
//! 1c. SIMD GEMV A/B: the batched decode projection kernel
//!    ([`matvec_t_batch_into`]) dispatch vs its scalar oracle.
//! 2. serving: coordinator throughput under a fixed KV budget, full vs
//!    CSKV backends — the operational payoff (more concurrency at equal
//!    memory).
//! 3. PJRT: per-step latency of the AOT `decode_full` vs `decode_cskv_r26`
//!    executables (the served artifacts; skipped if artifacts missing).
//!
//! Results are also written to `runs/BENCH_perf_decode.json`
//! (name → median ns + git rev) so the perf trajectory is tracked
//! across PRs.
//!
//! Run: `cargo bench --bench bench_perf_decode [-- --fast]`

use std::rc::Rc;
use std::sync::Arc;

use cskv::compress::quant::{quantize_block, QuantAxis, QuantizedBlock, GROUP};
use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::coordinator::pjrt_backend::{PjrtContext, PjrtCskvSession, PjrtFullSession};
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{Coordinator, CoordinatorConfig, RustSequenceBackend, SequenceBackend};
use cskv::data::tasks;
use cskv::eval::experiments::{factors_for, Env};
use cskv::finetune::recon::QatMode;
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::engine::DecodeState;
use cskv::runtime::Runtime;
use cskv::tensor::matmul::{axpy_row, dot, matvec_t_batch_into, matvec_t_batch_into_scalar};
use cskv::tensor::Mat;
use cskv::util::bench::{black_box, print_bench_header, Bencher};
use cskv::util::cli::Args;
use cskv::util::prng::Pcg64;
use cskv::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_perf_decode",
        "§Perf: decode latency + KV-budget serving throughput (headline ops win)",
    );
    let fast = args.get_flag("fast");
    let env = Env::load_default()?;
    let cfg = env.engine.w.cfg.clone();
    let plan = KvCompressionPlan::uniform(0.8);
    let factors = factors_for(&env, plan, InitMethod::asvd_default(), 0, QatMode::Off);

    // ---- 1. micro: decode step latency vs context ----------------------
    let mut b = if fast { Bencher::fast() } else { Bencher::new() };
    let mut rng = Pcg64::new(3);
    let variants: [(&str, Option<QuantMode>); 3] = [
        ("full", None),
        ("cskv80", Some(QuantMode::None)),
        ("cskv80-int4", Some(QuantMode::Int4)),
    ];
    let mk_policy = |quant: Option<QuantMode>| -> Box<dyn KvCachePolicy> {
        match quant {
            None => Box::new(FullCache::new(cfg.n_layers, cfg.d_model)),
            Some(q) => Box::new(CskvCache::new(
                Arc::clone(&factors),
                cfg.d_model,
                CskvConfig { window: 32, quant: q },
            )),
        }
    };
    for ctx in [128usize, 256, 509] {
        let prompt: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
        for (label, quant) in variants {
            // Incremental path: one persistent DecodeState, synced in
            // place each step (the production decode loop).
            let mut p = mk_policy(quant);
            let _ = env.engine.prefill(&prompt, Some(p.as_mut()));
            let mut state = DecodeState::new(&cfg);
            state.reserve(ctx + 512);
            p.reserve(512);
            let mut pos = ctx;
            b.time(&format!("rust decode/token {label} ctx={ctx}"), || {
                let _ = env.engine.decode_step_with(p.as_mut(), 42, pos, &mut state);
                pos += 1;
            });
        }
    }
    // Rematerialize rows: a fresh DecodeState every step forces the full
    // reconstruct + RoPE rebuild the pre-incremental engine paid per
    // token — the denominator of the headline speedup. Run at every
    // context so the O(context) growth of the baseline is on record.
    for ctx in [128usize, 256, 509] {
        let prompt: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
        for (label, quant) in variants {
            let mut p = mk_policy(quant);
            let _ = env.engine.prefill(&prompt, Some(p.as_mut()));
            let mut pos = ctx;
            b.time(&format!("rust decode/token {label} ctx={ctx} rematerialize"), || {
                let mut state = DecodeState::new(&cfg);
                let _ = env.engine.decode_step_with(p.as_mut(), 42, pos, &mut state);
                pos += 1;
            });
        }
        // Print the headline ratios (median-based).
        for (label, _) in variants {
            let med = |name: &str| -> Option<f64> {
                b.results()
                    .iter()
                    .find(|r| r.name == name)
                    .map(|r| r.samples.percentile(50.0))
            };
            if let (Some(inc), Some(remat)) = (
                med(&format!("rust decode/token {label} ctx={ctx}")),
                med(&format!("rust decode/token {label} ctx={ctx} rematerialize")),
            ) {
                if inc > 0.0 {
                    println!(
                        "speedup {label} ctx={ctx}: incremental views {:.2}x vs rematerialize",
                        remat / inc
                    );
                }
            }
        }
    }

    // ---- 1b. fused int4 attention kernel vs materialize-then-GEMV -------
    // The per-step choice the fused decode path wins: score/weight the
    // sealed history straight off the packed codes, or first dequantize
    // the groups into an f32 scratch and run the plain kernels (what a
    // non-fused implementation over packed storage must do every step).
    {
        let d = cfg.d_model;
        let (nh, dh) = (cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        for ctx in [128usize, 256, 509] {
            let n_groups = ctx / GROUP;
            let n_q = n_groups * GROUP;
            let mut kblocks: Vec<QuantizedBlock> = Vec::new();
            let mut vblocks: Vec<QuantizedBlock> = Vec::new();
            for _ in 0..n_groups {
                kblocks.push(quantize_block(&Mat::randn(GROUP, d, 1.0, &mut rng), QuantAxis::PerChannel));
                vblocks.push(quantize_block(&Mat::randn(GROUP, d, 1.0, &mut rng), QuantAxis::PerToken));
            }
            let q: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let mut scores = vec![0.0f32; n_q];
            let mut attn = vec![0.0f32; d];
            b.time(&format!("decode attn int4 fused ctx={ctx}"), || {
                attn.fill(0.0);
                for h in 0..nh {
                    let (lo, hi) = (h * dh, (h + 1) * dh);
                    for (gi, g) in kblocks.iter().enumerate() {
                        g.fused_dot_rows(&q[lo..hi], lo, hi, scale, &mut scores[gi * GROUP..(gi + 1) * GROUP]);
                    }
                    for (gi, g) in vblocks.iter().enumerate() {
                        g.fused_axpy_rows(&scores[gi * GROUP..(gi + 1) * GROUP], lo, hi, &mut attn[lo..hi]);
                    }
                }
                black_box(attn[0]);
            });
            let mut kmat = Mat::zeros(n_q, d);
            let mut vmat = Mat::zeros(n_q, d);
            b.time(&format!("decode attn int4 materialize ctx={ctx}"), || {
                for (gi, g) in kblocks.iter().enumerate() {
                    g.dequantize_rows_into(0, GROUP, &mut kmat.data[gi * GROUP * d..(gi + 1) * GROUP * d]);
                }
                for (gi, g) in vblocks.iter().enumerate() {
                    g.dequantize_rows_into(0, GROUP, &mut vmat.data[gi * GROUP * d..(gi + 1) * GROUP * d]);
                }
                attn.fill(0.0);
                for h in 0..nh {
                    let (lo, hi) = (h * dh, (h + 1) * dh);
                    for (i, s) in scores.iter_mut().enumerate() {
                        *s = dot(&q[lo..hi], &kmat.row(i)[lo..hi]) * scale;
                    }
                    for (i, s) in scores.iter().enumerate() {
                        axpy_row(&mut attn[lo..hi], *s, &vmat.row(i)[lo..hi]);
                    }
                }
                black_box(attn[0]);
            });
        }
        let med = |b: &Bencher, name: &str| -> Option<f64> {
            b.results()
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.samples.percentile(50.0))
        };
        for ctx in [128usize, 256, 509] {
            if let (Some(fused), Some(mat)) = (
                med(&b, &format!("decode attn int4 fused ctx={ctx}")),
                med(&b, &format!("decode attn int4 materialize ctx={ctx}")),
            ) {
                if fused > 0.0 {
                    println!(
                        "speedup int4 attn ctx={ctx}: fused {:.2}x vs materialize+GEMV{}",
                        mat / fused,
                        if ctx == 509 { "   <-- gate (>=1.3x)" } else { "" },
                    );
                }
            }
        }
    }

    // ---- 1c. SIMD batched decode GEMV vs scalar oracle ------------------
    {
        let (d_in, d_out, batch) = (cfg.d_model, cfg.d_ff, 8usize);
        let a = Mat::randn(d_in, d_out, 1.0, &mut rng);
        let xs = Mat::randn(batch, d_in, 1.0, &mut rng);
        let mut ys = Mat::zeros(batch, d_out);
        b.time(&format!("batched gemv {d_in}x{d_out} B={batch} simd-dispatch"), || {
            matvec_t_batch_into(&a, &xs, &mut ys);
            black_box(ys.data[0]);
        });
        b.time(&format!("batched gemv {d_in}x{d_out} B={batch} scalar-oracle"), || {
            matvec_t_batch_into_scalar(&a, &xs, &mut ys);
            black_box(ys.data[0]);
        });
        let med = |b: &Bencher, name: &str| -> Option<f64> {
            b.results()
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.samples.percentile(50.0))
        };
        if let (Some(dispatch), Some(scalar)) = (
            med(&b, &format!("batched gemv {d_in}x{d_out} B={batch} simd-dispatch")),
            med(&b, &format!("batched gemv {d_in}x{d_out} B={batch} scalar-oracle")),
        ) {
            if dispatch > 0.0 {
                println!(
                    "speedup batched gemv: simd dispatch {:.2}x vs scalar (feature {}){}",
                    scalar / dispatch,
                    if cfg!(feature = "simd") { "on" } else { "off" },
                    if cfg!(feature = "simd") { "   <-- gate (>=1.5x)" } else { "" },
                );
            }
        }
    }

    // ---- 2. serving throughput under a KV budget -----------------------
    let n_req = if fast { 8 } else { 24 };
    let budget = cfg.kv_bytes_full(512) * 2; // fits ~2 full-cache seqs
    let engine = env.engine.clone();
    let f2 = Arc::clone(&factors);
    let mk_setup = |use_cskv: bool| -> Setup {
        let engine = engine.clone();
        let f = Arc::clone(&f2);
        Box::new(move || {
            let factory: BackendFactory = Box::new(move || {
                let c = engine.w.cfg.clone();
                let policy: Box<dyn KvCachePolicy> = if use_cskv {
                    Box::new(CskvCache::new(
                        Arc::clone(&f),
                        c.d_model,
                        CskvConfig { window: 32, quant: QuantMode::None },
                    ))
                } else {
                    Box::new(FullCache::new(c.n_layers, c.d_model))
                };
                Ok(Box::new(RustSequenceBackend::new(engine.clone(), policy)))
            });
            Ok(factory)
        })
    };
    let mut t = Table::new(
        &format!("serving under KV budget = {} (max_batch 16, {n_req} reqs, ctx≈384)", cskv::util::table::bytes(budget)),
        &["backend", "throughput tok/s", "p95 ttft (s)", "max concurrency", "kv peak"],
    );
    for (label, use_cskv) in [("full", false), ("cskv80", true)] {
        let coord = Coordinator::start(
            mk_setup(use_cskv),
            CoordinatorConfig { max_batch: 16, kv_budget_bytes: Some(budget), ..Default::default() },
        );
        let mut rng = Pcg64::new(17);
        let rxs: Vec<_> = (0..n_req)
            .map(|_| coord.submit(tasks::line_retrieval_ctx(384, &mut rng).prompt, 8))
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let snap = coord.shutdown();
        t.row(&[
            label.to_string(),
            format!("{:.1}", snap.throughput_tok_s()),
            format!("{:.3}", snap.ttft_s.percentile(95.0)),
            snap.active_peak.to_string(),
            cskv::util::table::bytes(snap.kv_bytes_peak),
        ]);
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("perf_serving.csv"))?;

    // ---- 3. PJRT artifact decode latency -------------------------------
    if cskv::artifacts_dir().join("manifest.json").exists() {
        let rt = Runtime::load_default()?;
        rt.warmup(&["prefill", "decode_full", "decode_cskv_r26"])?;
        let ctx26 = Rc::new(PjrtContext::new(rt, Arc::clone(&env.engine.w))?);
        let mut rngp = Pcg64::new(21);
        let prompt: Vec<usize> = (0..384).map(|_| rngp.range(16, 250)).collect();

        let mut full = PjrtFullSession::new(Rc::clone(&ctx26));
        full.prefill(&prompt)?;
        b.time("pjrt decode_full step (ctx 384)", || {
            let _ = full.decode_next().unwrap();
        });

        let f26 = factors_for(&env, KvCompressionPlan::uniform(0.8), InitMethod::asvd_default(), 0, QatMode::Off);
        let mut cskv_sess = PjrtCskvSession::new(ctx26, f26)?;
        cskv_sess.prefill(&prompt)?;
        b.time("pjrt decode_cskv_r26 step (ctx 384, fused pallas)", || {
            let _ = cskv_sess.decode_next().unwrap();
        });
    } else {
        println!("(artifacts missing — PJRT section skipped; run `make artifacts`)");
    }

    // Machine-readable trajectory: name → median ns (+ git rev).
    let json_path = cskv::runs_dir().join("BENCH_perf_decode.json");
    b.write_json("bench_perf_decode", &json_path)?;
    println!("wrote {}", json_path.display());
    println!("done; see EXPERIMENTS.md §Perf for the recorded numbers");
    Ok(())
}
