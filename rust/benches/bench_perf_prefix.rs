//! §Perf — shared-prefix KV reuse: the coordinator's radix prefix cache,
//! cold vs warm.
//!
//! Workload per cell (depth × policy): `depth` requests that share a
//! `ctx`-token prompt prefix (a long system preamble) and differ only in
//! an 8-token tail. **Cold** serves them with the prefix cache disabled
//! — every request prefills its full prompt. **Warm** enables the cache
//! and first retires one pre-warm request carrying the shared prefix, so
//! the measured batch seeds from the trie and prefills only its 8-token
//! suffix.
//!
//! Reported per cell: TTFT p50/p95 cold and warm, warm speedup,
//! aggregate decode throughput, and the warm run's prefix hit rate
//! (`depth/(depth+1)` — every measured request hits; the pre-warm is
//! the one miss). Acceptance: warm TTFT p50 ≥ 2× better than cold at
//! ctx ≥ 256.
//!
//! Like the other perf benches the model comes from `ModelWeights::init`
//! so it runs anywhere (CI included; no pretrained weights needed).
//! Results land in `runs/BENCH_perf_prefix.json`.
//!
//! Run: `cargo bench --bench bench_perf_prefix [-- --fast]`

use std::sync::Arc;

use cskv::compress::{KvCompressionPlan, LayerFactors, LowRankFactors, ModelFactors};
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{Coordinator, CoordinatorConfig, RustSequenceBackend};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::engine::Engine;
use cskv::model::{ModelConfig, ModelWeights};
use cskv::tensor::Mat;
use cskv::util::bench::{git_rev, print_bench_header};
use cskv::util::cli::Args;
use cskv::util::json::Json;
use cskv::util::prng::Pcg64;
use cskv::util::stats::Samples;
use cskv::util::table::Table;

fn factors_for(cfg: &ModelConfig) -> Arc<ModelFactors> {
    let plan = KvCompressionPlan::uniform(0.8);
    let (rk, rv) = (plan.rank_k(cfg.d_model), plan.rank_v(cfg.d_model));
    let mut rng = Pcg64::new(11);
    let layers = (0..cfg.n_layers)
        .map(|_| LayerFactors {
            k: LowRankFactors::new(
                Mat::randn(cfg.d_model, rk, 0.2, &mut rng),
                Mat::randn(rk, cfg.d_model, 0.2, &mut rng),
            ),
            v: LowRankFactors::new(
                Mat::randn(cfg.d_model, rv, 0.2, &mut rng),
                Mat::randn(rv, cfg.d_model, 0.2, &mut rng),
            ),
        })
        .collect();
    Arc::new(ModelFactors {
        layers,
        provenance: "bench-prefix".into(),
    })
}

#[derive(Clone, Copy)]
enum Policy {
    Full,
    Cskv80,
    Cskv80Int4,
}

impl Policy {
    fn label(self) -> &'static str {
        match self {
            Policy::Full => "full",
            Policy::Cskv80 => "cskv80",
            Policy::Cskv80Int4 => "cskv80-int4",
        }
    }

    fn build(self, cfg: &ModelConfig, factors: &Arc<ModelFactors>) -> Box<dyn KvCachePolicy> {
        match self {
            Policy::Full => Box::new(FullCache::new(cfg.n_layers, cfg.d_model)),
            Policy::Cskv80 => Box::new(CskvCache::new(
                Arc::clone(factors),
                cfg.d_model,
                CskvConfig { window: 32, quant: QuantMode::None },
            )),
            Policy::Cskv80Int4 => Box::new(CskvCache::new(
                Arc::clone(factors),
                cfg.d_model,
                CskvConfig { window: 32, quant: QuantMode::Int4 },
            )),
        }
    }
}

struct Cell {
    ttft: Samples,
    tok_s: f64,
    hit_rate: Option<f64>,
    shared_bytes: u64,
}

/// Serve `depth` shared-prefix requests; `warm` enables the prefix cache
/// and retires one pre-warm request before the measured batch.
fn run_cell(
    engine: &Engine,
    factors: &Arc<ModelFactors>,
    policy: Policy,
    depth: usize,
    ctx: usize,
    warm: bool,
) -> anyhow::Result<Cell> {
    let cfg = engine.w.cfg.clone();
    let n_new = 8usize;
    let engine2 = engine.clone();
    let f2 = Arc::clone(factors);
    let cfg2 = cfg.clone();
    let setup: Setup = Box::new(move || {
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(RustSequenceBackend::new(
                engine2.clone(),
                policy.build(&cfg2, &f2),
            )))
        });
        Ok(factory)
    });
    let coord = Coordinator::start(
        setup,
        CoordinatorConfig {
            max_batch: depth,
            prefix_cache_bytes: warm.then_some(256 << 20),
            ..Default::default()
        },
    );

    let mut rng = Pcg64::new(23);
    let shared: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
    let mk = |tail_seed: u64| {
        let mut p = shared.clone();
        let mut r = Pcg64::new(tail_seed);
        p.extend((0..8).map(|_| r.range(16, 250)));
        p
    };
    if warm {
        // Pre-warm: one request publishes the shared prefix, off the
        // measured clock.
        let r = coord.submit(mk(1000), n_new).recv()?;
        anyhow::ensure!(r.error.is_none(), "pre-warm failed: {:?}", r.error);
    }
    let rxs: Vec<_> = (0..depth).map(|i| coord.submit(mk(i as u64), n_new)).collect();
    let mut ttft = Samples::new();
    for rx in rxs {
        let r = rx.recv()?;
        anyhow::ensure!(r.error.is_none(), "request failed: {:?}", r.error);
        ttft.push(r.ttft_s);
    }
    let snap = coord.shutdown();
    Ok(Cell {
        ttft,
        tok_s: snap.throughput_tok_s(),
        hit_rate: snap.prefix_hit_rate(),
        shared_bytes: snap.prefix_shared_bytes,
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_perf_prefix",
        "§Perf: radix prefix cache — cold vs warm TTFT for shared-prefix workloads",
    );
    let fast = args.get_flag("fast");
    let cfg = ModelConfig::tiny();
    let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 42)));
    let factors = factors_for(&cfg);
    let mut results = Json::obj();

    let depths: &[usize] = if fast { &[4] } else { &[4, 8] };
    let ctxs: &[usize] = if fast { &[64] } else { &[128, 256] };

    let mut t = Table::new(
        "prefix cache (depth requests sharing a ctx-token preamble, 8-token tails)",
        &[
            "depth",
            "ctx",
            "policy",
            "cold ttft p50 (s)",
            "cold p95",
            "warm ttft p50 (s)",
            "warm p95",
            "speedup",
            "tok/s warm",
            "hit rate",
        ],
    );
    for &depth in depths {
        for &ctx in ctxs {
            for policy in [Policy::Full, Policy::Cskv80, Policy::Cskv80Int4] {
                let cold = run_cell(&engine, &factors, policy, depth, ctx, false)?;
                let hot = run_cell(&engine, &factors, policy, depth, ctx, true)?;
                let (cp50, cp95) = (cold.ttft.percentile(50.0), cold.ttft.percentile(95.0));
                let (wp50, wp95) = (hot.ttft.percentile(50.0), hot.ttft.percentile(95.0));
                let speedup = cp50 / wp50;
                let hit_rate = hot.hit_rate.unwrap_or(0.0);
                let label = policy.label();
                if ctx >= 256 {
                    println!(
                        "warm-TTFT p50 {label} q{depth} ctx{ctx}: {speedup:.2}x vs cold \
                         (acceptance: >= 2.00x)"
                    );
                }
                t.row(&[
                    depth.to_string(),
                    ctx.to_string(),
                    label.to_string(),
                    format!("{cp50:.4}"),
                    format!("{cp95:.4}"),
                    format!("{wp50:.4}"),
                    format!("{wp95:.4}"),
                    format!("{speedup:.2}x"),
                    format!("{:.1}", hot.tok_s),
                    format!("{:.0}%", hit_rate * 100.0),
                ]);
                let key = |m: &str| format!("prefix_{label}_q{depth}_ctx{ctx}_{m}");
                results.set(&key("cold_ttft_p50_s"), Json::Num(cp50));
                results.set(&key("cold_ttft_p95_s"), Json::Num(cp95));
                results.set(&key("warm_ttft_p50_s"), Json::Num(wp50));
                results.set(&key("warm_ttft_p95_s"), Json::Num(wp95));
                results.set(&key("speedup_p50"), Json::Num(speedup));
                results.set(&key("warm_tok_s"), Json::Num(hot.tok_s));
                results.set(&key("hit_rate"), Json::Num(hit_rate));
                results.set(&key("shared_bytes"), Json::Num(hot.shared_bytes as f64));
            }
        }
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("perf_prefix.csv"))?;

    let root = Json::from_pairs(vec![
        ("bench", Json::Str("bench_perf_prefix".to_string())),
        (
            "git_rev",
            Json::Str(git_rev().unwrap_or_else(|| "unknown".to_string())),
        ),
        ("results", results),
    ]);
    let json_path = cskv::runs_dir().join("BENCH_perf_prefix.json");
    std::fs::write(&json_path, root.to_string_pretty())?;
    println!("wrote {}", json_path.display());
    println!("done; see EXPERIMENTS.md §Perf for the recorded numbers");
    Ok(())
}
