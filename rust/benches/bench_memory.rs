//! Memory accounting — the paper's intro claim and per-method KV
//! footprints.
//!
//! Part A (analytic, LLaMA-2-7B scale): reproduces "200K tokens ⇒ ~100GB
//! KV cache vs 14GB weights; >10× compression needed for a 24GB GPU".
//! Part B (measured, TinyLM): the *actual* bytes reported by every cache
//! policy after generation, cross-checked against the analytic model.
//!
//! Run: `cargo bench --bench bench_memory`

use std::sync::Arc;

use cskv::baselines::{H2oCache, StreamingLlmCache};
use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::eval::experiments::{factors_for, Env};
use cskv::finetune::recon::QatMode;
use cskv::kvcache::memory::{ArchSpec, GB};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::util::bench::print_bench_header;
use cskv::util::cli::Args;
use cskv::util::prng::Pcg64;
use cskv::util::table::{bytes, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_memory",
        "CSKV paper §1 intro claim + abstract's 80%/95% memory reductions",
    );

    // ---- Part A: analytic at LLaMA-2-7B scale -------------------------
    let arch = ArchSpec::llama2_7b();
    let mut t = Table::new(
        "KV memory at LLaMA-2-7B scale (fp16, analytic)",
        &["context", "weights", "full KV", "CSKV 80%", "CSKV 80%+int4", "pruned 80%"],
    );
    for tokens in [8_192usize, 32_768, 100_000, 200_000] {
        t.row(&[
            format!("{tokens}"),
            format!("{:.1}GB", arch.weight_bytes() as f64 / GB),
            format!("{:.1}GB", arch.kv_bytes_full(tokens) as f64 / GB),
            format!("{:.1}GB", arch.kv_bytes_cskv(tokens, 0.2, 32, false) as f64 / GB),
            format!("{:.1}GB", arch.kv_bytes_cskv(tokens, 0.2, 32, true) as f64 / GB),
            format!("{:.1}GB", arch.kv_bytes_pruned(tokens, 0.2) as f64 / GB),
        ]);
    }
    t.print();
    let full200k = arch.kv_bytes_full(200_000) as f64 / GB;
    println!(
        "intro claim: 200K tokens ⇒ {:.0}GB KV (paper: ~100GB), weights {:.0}GB (paper: 14GB)\n",
        full200k,
        arch.weight_bytes() as f64 / GB
    );

    // ---- Part B: measured on TinyLM ------------------------------------
    let env = Env::load_default()?;
    let cfg = env.engine.w.cfg.clone();
    let plan = KvCompressionPlan::uniform(0.8);
    let f = factors_for(&env, plan, InitMethod::asvd_default(), 0, QatMode::Off);

    let mut t = Table::new(
        "Measured KV bytes after generating 3 tokens (TinyLM, fp32)",
        &["context", "full", "StreamingLLM 80%", "H2O 80%", "CSKV 80%", "CSKV 80% int4", "cskv saving"],
    );
    let mut rng = Pcg64::new(9);
    for ctx in args.get_list_usize("ctx", &[128, 256, 509]) {
        let prompt: Vec<usize> = (0..ctx).map(|_| rng.range(16, 250)).collect();
        let run = |mut p: Box<dyn KvCachePolicy>| -> usize {
            let _ = env.engine.generate(&prompt, 3, p.as_mut());
            p.kv_bytes()
        };
        let full = run(Box::new(FullCache::new(cfg.n_layers, cfg.d_model)));
        let budget = (ctx / 5).max(6);
        let sl = run(Box::new(StreamingLlmCache::new(cfg.n_layers, cfg.d_model, 4, budget)));
        let h2o = run(Box::new(H2oCache::new(cfg.n_layers, cfg.d_model, budget)));
        let cs = run(Box::new(CskvCache::new(
            Arc::clone(&f),
            cfg.d_model,
            CskvConfig { window: 32, quant: QuantMode::None },
        )));
        let csq = run(Box::new(CskvCache::new(
            Arc::clone(&f),
            cfg.d_model,
            CskvConfig { window: 32, quant: QuantMode::Int4 },
        )));
        t.row(&[
            ctx.to_string(),
            bytes(full),
            bytes(sl),
            bytes(h2o),
            bytes(cs),
            bytes(csq),
            format!("{:.1}%", (1.0 - cs as f64 / full as f64) * 100.0),
        ]);
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("memory.csv"))?;
    println!("saved runs/memory.csv");
    Ok(())
}
