//! Table 1 — main results: {model} × {50%, 80%} × {StreamingLLM, H2O,
//! ASVD, CSKV} on LongEval / LongBench / LVEval (scaled suites).
//!
//! Run: `cargo bench --bench bench_table1_main [-- --samples 25 --fast]`

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::eval::experiments::{build_sets, eval_cell, factors_for, Env, Method, FT_STEPS};
use cskv::eval::Suite;
use cskv::finetune::recon::QatMode;
use cskv::kvcache::QuantMode;
use cskv::util::bench::print_bench_header;
use cskv::util::cli::Args;
use cskv::util::table::{acc, Table};

fn run_model_block(env: &Env, n_samples: usize, seed: u64, table: &mut Table) {
    let columns = Suite::table1_columns();
    let sets = build_sets(env, &columns, n_samples, seed);
    let header: Vec<String> = columns.iter().map(|(n, _)| n.clone()).collect();
    eprintln!("[{}] suites: {}", env.label, header.join(", "));

    let mut row = |cratio: &str, method: &Method| {
        let mut cells = vec![env.label.clone(), cratio.to_string(), method.label().to_string()];
        for ((_, suite), set) in columns.iter().zip(&sets) {
            let r = eval_cell(env, set, suite, method);
            cells.push(acc(r.agreement()));
        }
        table.row(&cells);
    };

    row("0%", &Method::Full);
    for ratio in [0.5f64, 0.8] {
        let plan = KvCompressionPlan::uniform(ratio);
        let asvd_f = factors_for(env, plan, InitMethod::asvd_default(), 0, QatMode::Off);
        let cskv_f = factors_for(env, plan, InitMethod::asvd_default(), FT_STEPS, QatMode::Off);
        let pct = format!("{}%", (ratio * 100.0) as u32);
        row(&pct, &Method::StreamingLlm { ratio });
        row(&pct, &Method::H2o { ratio });
        row(&pct, &Method::Asvd { factors: asvd_f });
        row(
            &pct,
            &Method::Cskv {
                factors: cskv_f,
                window: 32,
                quant: QuantMode::None,
            },
        );
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_table1_main",
        "CSKV paper Table 1 (methods × ratios × long-context suites)",
    );
    let n_samples = if args.get_flag("fast") {
        args.get_usize("samples", 8)
    } else {
        args.get_usize("samples", 25)
    };
    let seed = args.get_u64("seed", 42);

    let mut header = vec!["Model".to_string(), "C.Ratio".to_string(), "Method".to_string()];
    header.extend(Suite::table1_columns().into_iter().map(|(n, _)| n));
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Table 1: long-context performance", &hdr_refs);

    let env = Env::load_default()?;
    run_model_block(&env, n_samples, seed, &mut table);
    if let Some(env_b) = Env::load_secondary() {
        run_model_block(&env_b, n_samples, seed, &mut table);
    } else {
        eprintln!("(secondary model runs/tinylm_b.bin absent — single-model table)");
    }

    table.print();
    table.save_csv(&cskv::runs_dir().join("table1.csv"))?;
    println!("saved runs/table1.csv");
    Ok(())
}
