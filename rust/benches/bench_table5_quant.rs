//! Table 5 — integration with 4-bit quantization: origin ratio
//! {50,60,70,80}% × {None, PTQ, QAT}, KIVI-style int4 on the compressed
//! cache (per-channel K, per-token V), window = residual = 32.
//!
//! Run: `cargo bench --bench bench_table5_quant [-- --fast]`

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::eval::experiments::{build_sets, eval_cell, factors_for, Env, Method, FT_STEPS};
use cskv::eval::Suite;
use cskv::finetune::recon::QatMode;
use cskv::kvcache::QuantMode;
use cskv::util::bench::print_bench_header;
use cskv::util::cli::Args;
use cskv::util::table::{acc, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header("bench_table5_quant", "CSKV paper Table 5 (PTQ vs QAT int4)");
    let n = if args.get_flag("fast") { 8 } else { args.get_usize("samples", 25) };
    let seed = args.get_u64("seed", 46);
    let env = Env::load_default()?;

    let columns = Suite::ablation_columns();
    let sets = build_sets(&env, &columns, n, seed);
    let avg_of = |method: &Method| -> f64 {
        columns
            .iter()
            .zip(&sets)
            .map(|((_, suite), set)| eval_cell(&env, set, suite, method).agreement())
            .sum::<f64>()
            / columns.len() as f64
    };

    let mut t = Table::new(
        "Table 5: integration with int4 quantization (LongEval avg)",
        &["C.Ratio(origin)", "C.Ratio(4-bit)", "Q.Mode", "Avg.Acc"],
    );
    t.row(&["0%".into(), "0%".into(), "-".into(), acc(avg_of(&Method::Full))]);

    for ratio in [0.5f64, 0.6, 0.7, 0.8] {
        let plan = KvCompressionPlan::uniform(ratio);
        // Paper's fp16-baseline arithmetic: int4 is 4× on top of the
        // channel ratio (our fp32 store makes it 8×; both recorded).
        let total4 = 1.0 - (1.0 - ratio) / 4.0;
        let origin = format!("{}%", (ratio * 100.0) as u32);
        let total = format!("{:.1}%", total4 * 100.0);
        // None: fp32 compressed cache (fine-tuned without quant).
        let f_plain = factors_for(&env, plan, InitMethod::asvd_default(), FT_STEPS, QatMode::Off);
        let m_none = Method::Cskv {
            factors: std::sync::Arc::clone(&f_plain),
            window: 32,
            quant: QuantMode::None,
        };
        t.row(&[origin.clone(), total.clone(), "None".into(), acc(avg_of(&m_none))]);
        // PTQ: same factors, quantized at inference.
        let m_ptq = Method::Cskv {
            factors: f_plain,
            window: 32,
            quant: QuantMode::Int4,
        };
        t.row(&[origin.clone(), total.clone(), "PTQ".into(), acc(avg_of(&m_ptq))]);
        // QAT: fake-quant inside the reconstruction loss, then int4 serving.
        let f_qat = factors_for(&env, plan, InitMethod::asvd_default(), FT_STEPS, QatMode::Int4);
        let m_qat = Method::Cskv {
            factors: f_qat,
            window: 32,
            quant: QuantMode::Int4,
        };
        t.row(&[origin, total, "QAT".into(), acc(avg_of(&m_qat))]);
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("table5.csv"))?;
    println!("saved runs/table5.csv");
    Ok(())
}
