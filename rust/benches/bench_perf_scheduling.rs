//! §Perf — the preemptive tiered scheduler: fifo vs size-aware vs
//! preemptive under a mixed workload.
//!
//! Workload per cell (depth × policy × scheduler): `depth/2` long
//! requests (long prompt **and** long generation) are submitted first
//! and allowed to go hot; `depth` short requests then arrive mid-flight.
//! The KV budget hosts roughly one long sequence plus one short, so the
//! control plane decides everything:
//!
//! * `fifo` — shorts queue behind every not-yet-admitted long (head-of-
//!   line blocking): short TTFT ≈ the whole long backlog.
//! * `size-aware` — shorts jump the queue, but can't displace the long
//!   already occupying the budget: they trickle through the leftover
//!   headroom.
//! * `preemptive` — the hot long is swapped out to the cold tier
//!   (compressed snapshot), the shorts run as a batch, the long resumes
//!   bit-identically: short TTFT collapses toward a single round.
//!
//! Reported per cell: p50/p95 TTFT split short/long, aggregate
//! throughput, preemption/restore counts. Acceptance: short-request p50
//! TTFT improves vs `fifo` under every mixed cell, and `fifo` itself is
//! the unchanged PR 3 baseline (same admission behavior as
//! `bench_perf_serving`'s serving table).
//!
//! Like the other perf benches the model comes from `ModelWeights::init`
//! so it runs anywhere (CI included; no pretrained weights needed).
//! Results land in `runs/BENCH_perf_scheduling.json`.
//!
//! Run: `cargo bench --bench bench_perf_scheduling [-- --fast]`

use std::sync::Arc;

use cskv::compress::{KvCompressionPlan, LayerFactors, LowRankFactors, ModelFactors};
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{Coordinator, CoordinatorConfig, RustSequenceBackend, SchedulerKind};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::engine::Engine;
use cskv::model::{ModelConfig, ModelWeights};
use cskv::tensor::Mat;
use cskv::util::bench::{git_rev, print_bench_header};
use cskv::util::cli::Args;
use cskv::util::json::Json;
use cskv::util::prng::Pcg64;
use cskv::util::stats::Samples;
use cskv::util::table::Table;

fn factors_for(cfg: &ModelConfig) -> Arc<ModelFactors> {
    let plan = KvCompressionPlan::uniform(0.8);
    let (rk, rv) = (plan.rank_k(cfg.d_model), plan.rank_v(cfg.d_model));
    let mut rng = Pcg64::new(11);
    let layers = (0..cfg.n_layers)
        .map(|_| LayerFactors {
            k: LowRankFactors::new(
                Mat::randn(cfg.d_model, rk, 0.2, &mut rng),
                Mat::randn(rk, cfg.d_model, 0.2, &mut rng),
            ),
            v: LowRankFactors::new(
                Mat::randn(cfg.d_model, rv, 0.2, &mut rng),
                Mat::randn(rv, cfg.d_model, 0.2, &mut rng),
            ),
        })
        .collect();
    Arc::new(ModelFactors {
        layers,
        provenance: "bench-scheduling".into(),
    })
}

fn mk_policy(
    use_cskv: bool,
    cfg: &ModelConfig,
    factors: &Arc<ModelFactors>,
) -> Box<dyn KvCachePolicy> {
    if use_cskv {
        Box::new(CskvCache::new(
            Arc::clone(factors),
            cfg.d_model,
            CskvConfig { window: 32, quant: QuantMode::None },
        ))
    } else {
        Box::new(FullCache::new(cfg.n_layers, cfg.d_model))
    }
}

struct Cell {
    short_ttft: Samples,
    long_ttft: Samples,
    tok_s: f64,
    preemptions: u64,
    restores: u64,
}

/// One bench cell: workload shape + control-plane choice.
#[derive(Clone, Copy)]
struct CellSpec {
    use_cskv: bool,
    kind: SchedulerKind,
    depth: usize,
    ctx_long: usize,
    n_new_long: usize,
    n_new_short: usize,
}

fn run_cell(engine: &Engine, factors: &Arc<ModelFactors>, spec: CellSpec) -> anyhow::Result<Cell> {
    let CellSpec { use_cskv, kind, depth, ctx_long, n_new_long, n_new_short } = spec;
    let cfg = engine.w.cfg.clone();
    let ctx_short = 16usize;
    // Budget: one long sequence plus one short — admission beyond that is
    // purely the scheduler's call.
    let pricer = mk_policy(use_cskv, &cfg, factors);
    let budget = pricer.kv_bytes_projected(ctx_long + n_new_long)
        + pricer.kv_bytes_projected(ctx_short + n_new_short);
    drop(pricer);

    let engine2 = engine.clone();
    let f2 = Arc::clone(factors);
    let cfg2 = cfg.clone();
    let setup: Setup = Box::new(move || {
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(RustSequenceBackend::new(
                engine2.clone(),
                mk_policy(use_cskv, &cfg2, &f2),
            )))
        });
        Ok(factory)
    });
    let coord = Coordinator::start(
        setup,
        CoordinatorConfig {
            max_batch: depth,
            kv_budget_bytes: Some(budget),
            scheduler: kind,
            ..Default::default()
        },
    );

    let mut rng = Pcg64::new(17);
    let n_long = (depth / 2).max(1);
    let n_short = depth;
    // Phase 1: the long backlog goes in and gets hot.
    let long_rxs: Vec<_> = (0..n_long)
        .map(|_| {
            let prompt: Vec<usize> = (0..ctx_long).map(|_| rng.range(16, 250)).collect();
            coord.submit(prompt, n_new_long)
        })
        .collect();
    let t0 = std::time::Instant::now();
    while coord.metrics().kv_bytes_current() == 0 {
        anyhow::ensure!(t0.elapsed().as_secs() < 60, "long backlog never started");
        std::thread::yield_now();
    }
    // Phase 2: shorts arrive mid-flight.
    let short_rxs: Vec<_> = (0..n_short)
        .map(|_| {
            let prompt: Vec<usize> = (0..ctx_short).map(|_| rng.range(16, 250)).collect();
            coord.submit(prompt, n_new_short)
        })
        .collect();

    let mut short_ttft = Samples::new();
    for rx in short_rxs {
        let r = rx.recv()?;
        anyhow::ensure!(r.error.is_none(), "short request failed: {:?}", r.error);
        short_ttft.push(r.ttft_s);
    }
    let mut long_ttft = Samples::new();
    for rx in long_rxs {
        let r = rx.recv()?;
        anyhow::ensure!(r.error.is_none(), "long request failed: {:?}", r.error);
        long_ttft.push(r.ttft_s);
    }
    let snap = coord.shutdown();
    Ok(Cell {
        short_ttft,
        long_ttft,
        tok_s: snap.throughput_tok_s(),
        preemptions: snap.preemptions,
        restores: snap.restores,
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_perf_scheduling",
        "§Perf: preemptive tiered scheduler — fifo vs size-aware vs preemptive TTFT/throughput",
    );
    let fast = args.get_flag("fast");
    let cfg = ModelConfig::tiny();
    let engine = Engine::new(Arc::new(ModelWeights::init(&cfg, 42)));
    let factors = factors_for(&cfg);
    let mut results = Json::obj();

    let depths: &[usize] = if fast { &[4, 8] } else { &[4, 8, 16] };
    let ctx_long = if fast { 64 } else { 192 };
    let n_new_long = if fast { 24 } else { 48 };
    let n_new_short = 8usize;

    let mut t = Table::new(
        "scheduling (mixed workload: longs hot first, shorts arrive mid-flight)",
        &[
            "depth",
            "policy",
            "scheduler",
            "short ttft p50 (s)",
            "short ttft p95 (s)",
            "long ttft p50 (s)",
            "tok/s",
            "preempt/restore",
        ],
    );
    for &depth in depths {
        for (label, use_cskv) in [("full", false), ("cskv80", true)] {
            let mut fifo_short_p50 = f64::NAN;
            for kind in [
                SchedulerKind::Fifo,
                SchedulerKind::SizeAware,
                SchedulerKind::Preemptive,
            ] {
                let cell = run_cell(
                    &engine,
                    &factors,
                    CellSpec { use_cskv, kind, depth, ctx_long, n_new_long, n_new_short },
                )?;
                let sp50 = cell.short_ttft.percentile(50.0);
                let sp95 = cell.short_ttft.percentile(95.0);
                let lp50 = cell.long_ttft.percentile(50.0);
                if kind == SchedulerKind::Fifo {
                    fifo_short_p50 = sp50;
                } else {
                    println!(
                        "short-TTFT p50 {label} q{depth}: {} {:.2}x vs fifo \
                         (acceptance: improving, i.e. > 1.00x)",
                        kind.name(),
                        fifo_short_p50 / sp50
                    );
                }
                t.row(&[
                    depth.to_string(),
                    label.to_string(),
                    kind.name().to_string(),
                    format!("{sp50:.4}"),
                    format!("{sp95:.4}"),
                    format!("{lp50:.4}"),
                    format!("{:.1}", cell.tok_s),
                    format!("{}/{}", cell.preemptions, cell.restores),
                ]);
                let key = |m: &str| format!("sched_{}_{label}_q{depth}_{m}", kind.name());
                results.set(&key("short_ttft_p50_s"), Json::Num(sp50));
                results.set(&key("short_ttft_p95_s"), Json::Num(sp95));
                results.set(&key("long_ttft_p50_s"), Json::Num(lp50));
                results.set(&key("tok_s"), Json::Num(cell.tok_s));
                results.set(&key("preemptions"), Json::Num(cell.preemptions as f64));
                results.set(&key("restores"), Json::Num(cell.restores as f64));
            }
        }
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("perf_scheduling.csv"))?;

    let root = Json::from_pairs(vec![
        ("bench", Json::Str("bench_perf_scheduling".to_string())),
        (
            "git_rev",
            Json::Str(git_rev().unwrap_or_else(|| "unknown".to_string())),
        ),
        ("results", results),
    ]);
    let json_path = cskv::runs_dir().join("BENCH_perf_scheduling.json");
    std::fs::write(&json_path, root.to_string_pretty())?;
    println!("wrote {}", json_path.display());
    println!("done; see EXPERIMENTS.md §Perf for the recorded numbers");
    Ok(())
}
