//! Figure 4 — reconstruction-loss curves at 80% compression: ASVD vs SVD
//! vs random initialization.
//!
//! Reproduces the paper's observation: the random-init loss plateaus far
//! above the (A)SVD-init losses (which converge quickly), explaining the
//! 0.00 accuracies of random init in Table 2.
//!
//! Run: `cargo bench --bench bench_fig4_losscurve`

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::eval::experiments::Env;
use cskv::finetune::recon::QatMode;
use cskv::finetune::{build_factors, FinetuneConfig};
use cskv::util::bench::print_bench_header;
use cskv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_fig4_losscurve",
        "CSKV paper Figure 4 (recon loss: asvd vs svd vs random, 80% ratio)",
    );
    let env = Env::load_default()?;
    let steps = args.get_usize("steps", 300);
    let plan = KvCompressionPlan::uniform(0.8);

    let mut csv = String::from("init,step,loss\n");
    let mut finals = Vec::new();
    for (label, init) in [
        ("asvd", InitMethod::asvd_default()),
        ("svd", InitMethod::Svd),
        ("rand", InitMethod::Random),
    ] {
        let rep = build_factors(
            &env.engine.w,
            &env.calib,
            plan,
            &FinetuneConfig {
                init,
                steps,
                qat: QatMode::Off,
                ..Default::default()
            },
        );
        // Average the per-(layer,proj) curves into one series per init.
        let len = rep.curves[0].losses.len();
        let mut avg = vec![0.0f32; len];
        for c in &rep.curves {
            for (a, &l) in avg.iter_mut().zip(&c.losses) {
                *a += l / rep.curves.len() as f32;
            }
        }
        for (i, l) in avg.iter().enumerate() {
            csv.push_str(&format!("{label},{i},{l}\n"));
        }
        println!(
            "{label:>5}: loss[0]={:.6}  loss[{}]={:.6}  total(Eq.2)={:.6}",
            avg[0],
            len - 1,
            avg[len - 1],
            rep.final_total_loss
        );
        finals.push((label, rep.final_total_loss));
        // Compact ASCII curve (log-ish downsample).
        let marks: Vec<String> = (0..12)
            .map(|i| {
                let idx = (i * (len - 1)) / 11;
                format!("{:.4}", avg[idx])
            })
            .collect();
        println!("       curve: {}", marks.join(" → "));
    }
    let rand_final = finals.iter().find(|(l, _)| *l == "rand").unwrap().1;
    let asvd_final = finals.iter().find(|(l, _)| *l == "asvd").unwrap().1;
    println!(
        "\nshape check (paper: random ≫ svd/asvd): random/asvd final-loss ratio = {:.1}×",
        rand_final / asvd_final.max(1e-12)
    );
    std::fs::write(cskv::runs_dir().join("fig4_losscurves.csv"), csv)?;
    println!("saved runs/fig4_losscurves.csv");
    Ok(())
}
