//! Figure 3 — singular-value distribution of the key cache.
//!
//! Reproduces the paper's motivation plot: stack the key cache of a
//! middle layer over calibration documents, compute its spectrum, and
//! render the long-tail (plus the abstract's "drop the smallest 50% of
//! singular values ⇒ negligible damage" check).
//!
//! Run: `cargo bench --bench bench_fig3_svd`

use cskv::data::corpus::{calibration_docs, CorpusConfig};
use cskv::eval::experiments::Env;
use cskv::eval::svd_analysis::analyze_key_cache;
use cskv::util::bench::print_bench_header;
use cskv::util::cli::Args;
use cskv::util::stats::Histogram;
use cskv::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_fig3_svd",
        "CSKV paper Figure 3 (key-cache singular values) + abstract's 50% check",
    );
    let env = Env::load_default()?;
    let n_docs = args.get_usize("docs", 8);
    let docs = calibration_docs(&CorpusConfig::default(), n_docs, 123);

    let mut csv = String::from("layer,index,singular_value,cum_energy\n");
    for layer in 0..env.n_layers() {
        let rep = analyze_key_cache(&env.engine, &docs, layer);
        println!(
            "layer {layer}: top σ = {:.3}, median σ = {:.4}, drop-half rel err = {:.4}",
            rep.singular_values[0],
            rep.singular_values[rep.singular_values.len() / 2],
            rep.half_rank_rel_error
        );
        // Long-tail summary: energy captured by top-k.
        let mut t = Table::new(
            &format!("Figure 3 (layer {layer}): cumulative spectral energy"),
            &["top-k", "fraction of ‖K‖² captured"],
        );
        for k in [1usize, 2, 4, 8, 16, 26, 32, 64, 128] {
            if k <= rep.cum_energy.len() {
                t.row(&[k.to_string(), format!("{:.4}", rep.cum_energy[k - 1])]);
            }
        }
        t.print();
        // ASCII histogram of the spectrum (the figure itself).
        let max_sv = rep.singular_values[0] as f64;
        let mut h = Histogram::new(0.0, max_sv.max(1e-6), 24);
        for &s in &rep.singular_values {
            h.push(s as f64);
        }
        println!("σ distribution (layer {layer}):\n{}", h.render(48));
        for (i, &s) in rep.singular_values.iter().enumerate() {
            csv.push_str(&format!("{layer},{i},{s},{}\n", rep.cum_energy[i]));
        }
    }
    std::fs::write(cskv::runs_dir().join("fig3_singular_values.csv"), csv)?;
    println!("saved runs/fig3_singular_values.csv");
    Ok(())
}
