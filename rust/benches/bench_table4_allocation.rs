//! Table 4 — K/V compression-ratio allocation at total {50%, 75%}:
//! every paper split from K-heavy to V-heavy.
//!
//! Run: `cargo bench --bench bench_table4_allocation [-- --fast]`

use cskv::compress::ratio::table4_allocations;
use cskv::compress::InitMethod;
use cskv::eval::experiments::{build_sets, eval_cell, factors_for, Env, Method, FT_STEPS};
use cskv::eval::Suite;
use cskv::finetune::recon::QatMode;
use cskv::kvcache::QuantMode;
use cskv::util::bench::print_bench_header;
use cskv::util::cli::Args;
use cskv::util::table::{acc, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    print_bench_header(
        "bench_table4_allocation",
        "CSKV paper Table 4 (K/V ratio allocation)",
    );
    let n = if args.get_flag("fast") { 8 } else { args.get_usize("samples", 25) };
    let seed = args.get_u64("seed", 45);
    let env = Env::load_default()?;

    let columns = Suite::ablation_columns();
    let sets = build_sets(&env, &columns, n, seed);
    let avg_of = |method: &Method| -> f64 {
        columns
            .iter()
            .zip(&sets)
            .map(|((_, suite), set)| eval_cell(&env, set, suite, method).agreement())
            .sum::<f64>()
            / columns.len() as f64
    };

    let mut t = Table::new(
        "Table 4: K/V allocation (keep fractions; LongEval avg)",
        &["C.Ratio", "KV C.Ratio", "Avg.Acc"],
    );
    t.row(&["0%".into(), "-".into(), acc(avg_of(&Method::Full))]);

    for total in [0.5f64, 0.75] {
        for plan in table4_allocations(total) {
            let f = factors_for(&env, plan, InitMethod::asvd_default(), FT_STEPS, QatMode::Off);
            let m = Method::Cskv {
                factors: f,
                window: 32,
                quant: QuantMode::None,
            };
            t.row(&[
                format!("{}%", (total * 100.0) as u32),
                format!(
                    "K({:.2}%) V({:.2}%)",
                    plan.keep_k * 100.0,
                    plan.keep_v * 100.0
                ),
                acc(avg_of(&m)),
            ]);
        }
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("table4.csv"))?;
    println!("saved runs/table4.csv");
    Ok(())
}
