"""L2 model correctness: shapes, decode-path consistency, training step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = M.TEST_SMALL
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, 12), jnp.int32)
    return cfg, params, toks


class TestForward:
    def test_logit_shapes(self, setup):
        cfg, params, toks = setup
        logits = M.forward_tokens(cfg, params, toks)
        assert logits.shape == (12, cfg.vocab_size)
        batched = M.forward_batch(cfg, params, jnp.stack([toks, toks]))
        assert batched.shape == (2, 12, cfg.vocab_size)
        np.testing.assert_allclose(np.asarray(batched[0]), np.asarray(logits), atol=1e-5)

    def test_causality(self, setup):
        """Changing a later token must not affect earlier logits."""
        cfg, params, toks = setup
        a = M.forward_tokens(cfg, params, toks)
        mutated = toks.at[8].set((toks[8] + 1) % cfg.vocab_size)
        b = M.forward_tokens(cfg, params, mutated)
        np.testing.assert_allclose(np.asarray(a[:8]), np.asarray(b[:8]), atol=1e-5)
        assert not np.allclose(np.asarray(a[8:]), np.asarray(b[8:]))

    def test_prefill_padding_harmless(self, setup):
        cfg, params, toks = setup
        want = M.forward_tokens(cfg, params, toks)
        padded = jnp.pad(toks, (0, cfg.max_seq - toks.shape[0]))
        logits, xns, ks, vs = M.prefill(cfg, params, padded)
        np.testing.assert_allclose(
            np.asarray(logits[:12]), np.asarray(want), atol=2e-3
        )
        assert xns.shape == (cfg.n_layers, cfg.max_seq, cfg.d_model)


class TestDecodeConsistency:
    def _seed_buffers(self, cfg, params, toks, t0):
        padded = jnp.pad(toks[:t0], (0, cfg.max_seq - t0))
        _, xns, ks, vs = M.prefill(cfg, params, padded)
        kbuf = np.zeros((cfg.n_layers, cfg.max_seq, cfg.d_model), np.float32)
        vbuf = np.zeros_like(kbuf)
        pos = jnp.arange(t0)
        for li in range(cfg.n_layers):
            kbuf[li, :t0] = np.asarray(M.rope(ks[li, :t0], pos, cfg.n_heads, cfg.rope_base))
            vbuf[li, :t0] = np.asarray(vs[li, :t0])
        return xns, ks, vs, kbuf, vbuf

    def test_decode_full_matches_forward(self, setup):
        cfg, params, toks = setup
        want = M.forward_tokens(cfg, params, toks)
        t0 = 4
        _, _, _, kbuf, vbuf = self._seed_buffers(cfg, params, toks, t0)
        for i in range(t0, toks.shape[0]):
            lg, kn, vn = M.decode_full(
                cfg, params, toks[i], jnp.int32(i), jnp.asarray(kbuf), jnp.asarray(vbuf)
            )
            np.testing.assert_allclose(np.asarray(lg), np.asarray(want[i]), atol=3e-3)
            kbuf[:, i] = np.asarray(kn)
            vbuf[:, i] = np.asarray(vn)

    def test_decode_cskv_exact_factors_matches_forward(self, setup):
        """With factors that reproduce W_K/W_V exactly (A=W, B=I), the
        bi-branch decode must equal the dense forward pass."""
        cfg, params, toks = setup
        want = M.forward_tokens(cfg, params, toks)
        d = cfg.d_model
        eye = jnp.eye(d)
        ak = jnp.stack([params[1 + li * 8 + 2] for li in range(cfg.n_layers)])  # wk
        av = jnp.stack([params[1 + li * 8 + 3] for li in range(cfg.n_layers)])  # wv
        bk = jnp.stack([eye] * cfg.n_layers)
        bv = bk
        t0, win = 4, 8
        padded = jnp.pad(toks[:t0], (0, cfg.max_seq - t0))
        _, xns, _, _ = M.prefill(cfg, params, padded)
        ck = np.zeros((cfg.n_layers, cfg.max_seq, d), np.float32)
        cv = np.zeros_like(ck)
        for li in range(cfg.n_layers):
            ck[li, :t0] = np.asarray(xns[li, :t0] @ ak[li])
            cv[li, :t0] = np.asarray(xns[li, :t0] @ av[li])
        win_k = np.zeros((cfg.n_layers, win, d), np.float32)
        win_v = np.zeros_like(win_k)
        win_pos = np.zeros((cfg.n_layers, win), np.int32)
        n = t0
        for i in range(t0, toks.shape[0]):
            lg, ckn, cvn, kn, vn = M.decode_cskv(
                cfg, params, ak, bk, av, bv,
                toks[i], jnp.int32(n), jnp.int32(0),
                jnp.asarray(ck), jnp.asarray(cv),
                jnp.asarray(win_k), jnp.asarray(win_v), jnp.asarray(win_pos),
            )
            np.testing.assert_allclose(np.asarray(lg), np.asarray(want[i]), atol=5e-3)
            ck[:, n] = np.asarray(ckn)
            cv[:, n] = np.asarray(cvn)
            n += 1

    def test_decode_cskv_window_branch(self, setup):
        """Window rows must be used verbatim: with garbage factors but the
        whole history inside the window, decode must still be exact."""
        cfg, params, toks = setup
        want = M.forward_tokens(cfg, params, toks)
        d = cfg.d_model
        rng = np.random.default_rng(7)
        r = 4
        junk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
        ak, av = junk(cfg.n_layers, d, r), junk(cfg.n_layers, d, r)
        bk, bv = junk(cfg.n_layers, r, d), junk(cfg.n_layers, r, d)
        t0, win = 4, 16
        padded = jnp.pad(toks[:t0], (0, cfg.max_seq - t0))
        _, xns, ks, vs = M.prefill(cfg, params, padded)
        ck = np.zeros((cfg.n_layers, cfg.max_seq, r), np.float32)
        cv = np.zeros_like(ck)
        win_k = np.zeros((cfg.n_layers, win, d), np.float32)
        win_v = np.zeros_like(win_k)
        win_pos = np.zeros((cfg.n_layers, win), np.int32)
        # Put ALL t0 tokens in the window (win_len = t0).
        for li in range(cfg.n_layers):
            win_k[li, :t0] = np.asarray(ks[li, :t0])
            win_v[li, :t0] = np.asarray(vs[li, :t0])
            win_pos[li, :t0] = np.arange(t0)
        n, win_len = t0, t0
        for i in range(t0, min(toks.shape[0], t0 + win - t0)):
            lg, ckn, cvn, kn, vn = M.decode_cskv(
                cfg, params, ak, bk, av, bv,
                toks[i], jnp.int32(n), jnp.int32(win_len),
                jnp.asarray(ck), jnp.asarray(cv),
                jnp.asarray(win_k), jnp.asarray(win_v), jnp.asarray(win_pos),
            )
            np.testing.assert_allclose(np.asarray(lg), np.asarray(want[i]), atol=5e-3)
            # Roll the new token into the window (window not yet full).
            for li in range(cfg.n_layers):
                win_k[li, win_len] = np.asarray(kn[li])
                win_v[li, win_len] = np.asarray(vn[li])
                win_pos[li, win_len] = n
            win_len += 1
            n += 1


class TestTrainStep:
    def test_loss_decreases(self, setup):
        cfg, params, _ = setup
        rng = np.random.default_rng(5)
        B, T = 2, 24
        x = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, T)), jnp.int32)
        y = jnp.roll(x, -1, axis=1)
        mask = jnp.ones((B, T), jnp.float32)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        p = params
        losses = []
        for step in range(12):
            p, m, v, loss = M.train_step(cfg, p, m, v, jnp.int32(step), x, y, mask, jnp.float32(2e-3))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.9, losses

    def test_mask_excludes_positions(self, setup):
        cfg, params, _ = setup
        rng = np.random.default_rng(6)
        B, T = 1, 16
        x = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, T)), jnp.int32)
        y = jnp.roll(x, -1, axis=1)
        full = M.loss_fn(cfg, params, x, y, jnp.ones((B, T), jnp.float32))
        # Masking everything but one position changes the loss value.
        m1 = jnp.zeros((B, T), jnp.float32).at[0, 3].set(1.0)
        partial = M.loss_fn(cfg, params, x, y, m1)
        assert not np.isclose(float(full), float(partial))

    def test_param_shapes_contract(self):
        cfg = M.TINY
        shapes = M.param_shapes(cfg)
        assert shapes[0] == ("embed", (256, 128))
        assert shapes[1][0] == "layers.0.ln1"
        assert shapes[-1] == ("lm_head", (128, 256))
        assert len(shapes) == 3 + 8 * cfg.n_layers
