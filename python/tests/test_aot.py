"""AOT pipeline: HLO text emission and manifest integrity.

Full lowering of the TINY config is exercised by ``make artifacts``; here
we lower the small test config end-to-end (fast) and sanity-check the
shipped manifest when artifacts exist.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


class TestLowering:
    def test_hlo_text_roundtrips_small_fn(self):
        import jax

        fn = jax.jit(lambda x, y: (x @ y + 1.0,))
        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        text = aot.to_hlo_text(fn.lower(spec, spec))
        assert "HloModule" in text
        assert "f32[4,4]" in text

    def test_decode_cskv_lowering_small(self):
        cfg = M.TEST_SMALL
        lowered, inputs, outputs, static = aot.build_decode_cskv(cfg, rank=8)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert static["rank"] == 8
        # input count: params + 4 factors + 3 scalars + 5 buffers
        assert len(inputs) == len(M.param_shapes(cfg)) + 12
        assert outputs[0]["name"] == "logits"

    def test_prefill_lowering_small(self):
        cfg = M.TEST_SMALL
        lowered, inputs, outputs, static = aot.build_prefill(cfg)
        text = aot.to_hlo_text(lowered)
        assert f"f32[{cfg.n_layers},{cfg.max_seq},{cfg.d_model}]" in text
        assert [o["name"] for o in outputs] == ["logits", "xnorms", "ks", "vs"]


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)
class TestShippedManifest:
    def test_manifest_consistent_with_files(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            man = json.load(f)
        assert man["format"] == "hlo-text-v1"
        assert man["model"]["d_model"] == 128
        for name, exe in man["executables"].items():
            path = os.path.join(ARTIFACTS, exe["file"])
            assert os.path.exists(path), f"{name}: missing {exe['file']}"
            head = open(path).read(200)
            assert "HloModule" in head, f"{name}: not HLO text"
            assert len(exe["inputs"]) > 0 and len(exe["outputs"]) > 0

    def test_train_step_io_counts(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            man = json.load(f)
        if "train_step" not in man["executables"]:
            pytest.skip("train_step skipped at lowering time")
        exe = man["executables"]["train_step"]
        n_params = len(M.param_shapes(M.TINY))
        assert len(exe["inputs"]) == 3 * n_params + 5
        assert len(exe["outputs"]) == 3 * n_params + 1

    def test_decode_cskv_ranks_exported(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            man = json.load(f)
        ranks = sorted(
            exe["static"]["rank"]
            for name, exe in man["executables"].items()
            if name.startswith("decode_cskv")
        )
        # 50% and 80% compression of d_model=128.
        assert ranks == [26, 64]
