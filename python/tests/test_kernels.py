"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/values; fixed cases pin the exact serving shapes
used by the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bibranch_attn, int4_quant, lowrank_proj, ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# lowrank_proj
# ---------------------------------------------------------------------------

class TestLowrankProj:
    @settings(**SETTINGS)
    @given(
        n=st.integers(1, 300),
        d=st.sampled_from([16, 32, 128]),
        r=st.sampled_from([4, 26, 64]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, n, d, r, seed):
        rng = np.random.default_rng(seed)
        x, a = rand(rng, n, d), rand(rng, d, r)
        got = lowrank_proj.project(x, a)
        want = ref.project_ref(x, a)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)

    def test_serving_shapes(self):
        # The exact shapes the decode_cskv artifact uses (d=128, r=26/64).
        rng = np.random.default_rng(0)
        for r in (26, 64):
            x, a = rand(rng, 1, 128), rand(rng, 128, r)
            got = lowrank_proj.project(x, a)
            assert got.shape == (1, r)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(ref.project_ref(x, a)), atol=1e-4
            )

    def test_tail_tile_padding(self):
        # n not a multiple of BLOCK_ROWS exercises the padded tail tile.
        rng = np.random.default_rng(1)
        n = lowrank_proj.BLOCK_ROWS * 2 + 3
        x, a = rand(rng, n, 32), rand(rng, 32, 8)
        np.testing.assert_allclose(
            np.asarray(lowrank_proj.project(x, a)),
            np.asarray(ref.project_ref(x, a)),
            atol=1e-3,
        )

    def test_vmem_estimate_positive(self):
        assert lowrank_proj.vmem_bytes(128, 26) > 0


# ---------------------------------------------------------------------------
# bibranch_attn
# ---------------------------------------------------------------------------

class TestBibranchAttn:
    @settings(**SETTINGS)
    @given(
        hist=st.integers(0, 512),
        rk=st.sampled_from([8, 26, 64]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, hist, rk, seed):
        rng = np.random.default_rng(seed)
        H, dh, maxT = 4, 32, 512
        d = H * dh
        q = rand(rng, d)
        ck, bk = rand(rng, maxT, rk), rand(rng, rk, d)
        cv, bv = rand(rng, maxT, rk), rand(rng, rk, d)
        o1, m1, l1 = bibranch_attn.hist_attention(q, ck, bk, cv, bv, hist, H, 10000.0)
        o2, m2, l2 = ref.hist_attention_ref(q, ck, bk, cv, bv, hist, H, 10000.0)
        np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-3)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-2, atol=1e-3)

    def test_empty_history_is_neutral(self):
        # hist=0: the partial state must be the online-softmax identity
        # (o=0, l=0, m=NEG) so merging it changes nothing.
        rng = np.random.default_rng(2)
        H, dh, rk, maxT = 4, 8, 6, 64
        d = H * dh
        o, m, l = bibranch_attn.hist_attention(
            rand(rng, d), rand(rng, maxT, rk), rand(rng, rk, d),
            rand(rng, maxT, rk), rand(rng, rk, d), 0, H, 10000.0,
        )
        assert float(jnp.max(jnp.abs(o))) == 0.0
        assert float(jnp.max(jnp.abs(l))) == 0.0
        assert float(jnp.max(m)) <= bibranch_attn.NEG / 2

    def test_merge_recovers_full_attention(self):
        """Splitting the cache into hist+window and merging partial states
        must equal dense attention over the concatenation — the algebra the
        bi-branch decode relies on."""
        from compile import model as M

        rng = np.random.default_rng(3)
        H, dh, maxT = 4, 8, 64
        d = H * dh
        hist, extra = 40, 10
        q = rand(rng, d)
        # Low-rank history (exact: full-rank factors = identity).
        eye = jnp.eye(d)
        k_all = rand(rng, hist + extra, d)
        v_all = rand(rng, hist + extra, d)
        pos = jnp.arange(hist + extra)
        k_roped = ref.rope_ref(k_all, pos, H, 10000.0)
        # hist part through the kernel (identity factors, pre-RoPE rows).
        ck = jnp.zeros((maxT, d)).at[:hist].set(k_all[:hist])
        cv = jnp.zeros((maxT, d)).at[:hist].set(v_all[:hist])
        o1, m1, l1 = bibranch_attn.hist_attention(q, ck, eye, cv, eye, hist, H, 10000.0)
        # window part dense.
        o2, m2, l2 = M._dense_attn_partial(
            q, k_roped[hist:], v_all[hist:], H, jnp.ones((extra,), bool)
        )
        o, m, l = M._merge_softmax(o1, m1, l1, o2, m2, l2)
        got = (o / l[:, None]).reshape(d)
        want = ref.softmax_attention_ref(q, k_roped, v_all, H)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)

    def test_vmem_estimate_fits_tpu_budget(self):
        # The DESIGN.md claim: the schedule fits a ~16 MiB VMEM easily.
        assert bibranch_attn.vmem_bytes(26, 26, 128) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# int4_quant
# ---------------------------------------------------------------------------

class TestInt4Quant:
    @settings(**SETTINGS)
    @given(
        g=st.integers(2, 64),
        r=st.integers(2, 64),
        axis=st.sampled_from(["per_channel", "per_token"]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, g, r, axis, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, g, r)
        np.testing.assert_allclose(
            np.asarray(int4_quant.fake_quant(x, axis)),
            np.asarray(ref.fake_quant_ref(x, axis)),
            atol=1e-5,
        )

    @settings(**SETTINGS)
    @given(axis=st.sampled_from(["per_channel", "per_token"]), seed=st.integers(0, 2**31))
    def test_error_within_half_step(self, axis, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, 32, 26)
        dq = np.asarray(int4_quant.fake_quant(x, axis))
        ax = 0 if axis == "per_channel" else 1
        xn = np.asarray(x)
        step = (xn.max(axis=ax) - xn.min(axis=ax)).max() / 15.0
        assert np.abs(dq - xn).max() <= step / 2 + 1e-5

    def test_idempotent(self):
        rng = np.random.default_rng(4)
        x = rand(rng, 16, 8)
        once = int4_quant.fake_quant(x, "per_token")
        twice = int4_quant.fake_quant(once, "per_token")
        np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-5)
