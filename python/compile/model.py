"""Layer-2: TinyLM in JAX — forward/backward and the serving step functions.

This module is BUILD-TIME ONLY: `aot.py` lowers the jitted entry points to
HLO text once (``make artifacts``); the Rust coordinator executes them via
PJRT and Python never runs on the request path.

The architecture mirrors ``rust/src/model/engine.rs`` exactly (pre-norm,
RMSNorm, rotate-half RoPE, causal MHA, SiLU MLP, untied head); the Rust
test-suite cross-validates logits between the two implementations through
the AOT artifacts.

Parameter flattening follows ``ModelWeights::flat_order`` on the Rust side:
``embed, [ln1, wq, wk, wv, wo, ln2, w1, w2] * n_layers, ln_f, lm_head``.
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .kernels import bibranch_attn, lowrank_proj


@dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 512
    rope_base: float = 10000.0
    eps: float = 1e-5

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def to_json_dict(self):
        return {
            "vocab_size": self.vocab_size,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "d_ff": self.d_ff,
            "max_seq": self.max_seq,
            "rope_base": self.rope_base,
            "eps": self.eps,
        }


TINY = ModelConfig()
WIDE = ModelConfig(d_model=192, n_heads=6, d_ff=768)
TEST_SMALL = ModelConfig(d_model=32, n_layers=2, n_heads=2, d_ff=64, max_seq=128)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig):
    """Flat (name, shape) list — the interchange contract with Rust."""
    shapes = [("embed", (cfg.vocab_size, cfg.d_model))]
    for i in range(cfg.n_layers):
        shapes += [
            (f"layers.{i}.ln1", (1, cfg.d_model)),
            (f"layers.{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"layers.{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"layers.{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"layers.{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"layers.{i}.ln2", (1, cfg.d_model)),
            (f"layers.{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"layers.{i}.w2", (cfg.d_ff, cfg.d_model)),
        ]
    shapes += [("ln_f", (1, cfg.d_model)), ("lm_head", (cfg.d_model, cfg.vocab_size))]
    return shapes


def init_params(cfg: ModelConfig, key):
    """GPT-style init, matching ModelWeights::init statistically."""
    params = []
    out_std = 0.02 / (2.0 * cfg.n_layers) ** 0.5
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if ".ln" in name or name == "ln_f":
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".wo") or name.endswith(".w2"):
            params.append(jax.random.normal(sub, shape, jnp.float32) * out_std)
        else:
            params.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
    return params


def unflatten(cfg: ModelConfig, params):
    """Split the flat list into (embed, layers, ln_f, lm_head)."""
    embed = params[0]
    layers = []
    for i in range(cfg.n_layers):
        o = 1 + i * 8
        layers.append(
            dict(
                ln1=params[o],
                wq=params[o + 1],
                wk=params[o + 2],
                wv=params[o + 3],
                wo=params[o + 4],
                ln2=params[o + 5],
                w1=params[o + 6],
                w2=params[o + 7],
            )
        )
    return embed, layers, params[-2], params[-1]


# --------------------------------------------------------------------------
# Primitives (must match rust/src/tensor/ops.rs)
# --------------------------------------------------------------------------

def rmsnorm(x, gain, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain.reshape(-1)


def rope(x, positions, n_heads, base):
    """Rotate-half RoPE. x: [T, d_model]; positions: [T]."""
    t, dm = x.shape
    d = dm // n_heads
    half = d // 2
    xh = x.reshape(t, n_heads, d)
    theta = base ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / d)
    ang = positions.astype(jnp.float32)[:, None] * theta[None, :]  # [T, half]
    sin = jnp.sin(ang)[:, None, :]  # [T, 1, half]
    cos = jnp.cos(ang)[:, None, :]
    a, b = xh[..., :half], xh[..., half:]
    rot = jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)
    return rot.reshape(t, dm)


def attention_causal(q, k, v, n_heads):
    """q,k,v: [T, d_model] (already RoPE'd). Causal MHA."""
    t, d = q.shape
    dh = d // n_heads
    qh = q.reshape(t, n_heads, dh).transpose(1, 0, 2)  # [H,T,dh]
    kh = k.reshape(t, n_heads, dh).transpose(1, 0, 2)
    vh = v.reshape(t, n_heads, dh).transpose(1, 0, 2)
    scores = jnp.einsum("htd,hsd->hts", qh, kh) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hts,hsd->htd", probs, vh)
    return out.transpose(1, 0, 2).reshape(t, d)


# --------------------------------------------------------------------------
# Training forward (pure jnp — differentiable)
# --------------------------------------------------------------------------

def forward_tokens(cfg: ModelConfig, params, tokens):
    """tokens: [T] int32 -> logits [T, vocab]. Single sequence."""
    embed, layers, ln_f, lm_head = unflatten(cfg, params)
    x = embed[tokens]
    pos = jnp.arange(tokens.shape[0])
    for lw in layers:
        xn = rmsnorm(x, lw["ln1"], cfg.eps)
        q = rope(xn @ lw["wq"], pos, cfg.n_heads, cfg.rope_base)
        k = rope(xn @ lw["wk"], pos, cfg.n_heads, cfg.rope_base)
        v = xn @ lw["wv"]
        x = x + attention_causal(q, k, v, cfg.n_heads) @ lw["wo"]
        xn2 = rmsnorm(x, lw["ln2"], cfg.eps)
        x = x + jax.nn.silu(xn2 @ lw["w1"]) @ lw["w2"]
    return rmsnorm(x, ln_f, cfg.eps) @ lm_head


def forward_batch(cfg: ModelConfig, params, tokens):
    """tokens: [B, T] -> logits [B, T, vocab]."""
    return jax.vmap(lambda t: forward_tokens(cfg, params, t))(tokens)


def loss_fn(cfg: ModelConfig, params, x, y, mask):
    """Masked mean cross-entropy. x,y: [B,T] int32; mask: [B,T] f32."""
    logits = forward_batch(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train_step(cfg: ModelConfig, params, m, v, step, x, y, mask, lr):
    """One Adam step. Flat lists in, flat lists out (PJRT-friendly).

    Returns (new_params, new_m, new_v, loss).
    """
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, x, y, mask))(params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step.astype(jnp.float32) + 1.0
    b1t = 1.0 - b1 ** t
    b2t = 1.0 - b2 ** t
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * g * g
        update = (mi / b1t) / (jnp.sqrt(vi / b2t) + eps)
        new_p.append(p - lr * update)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, loss


# --------------------------------------------------------------------------
# Serving: prefill + decode steps (what the Rust coordinator executes)
# --------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens):
    """tokens: [T] int32 (PAD-padded to max_seq; causal masking makes the
    padding harmless for earlier rows).

    Returns (logits [T,V], xnorms [L,T,d], ks [L,T,d] (pre-RoPE),
    vs [L,T,d]) — everything Rust needs to seed any cache policy.
    """
    embed, layers, ln_f, lm_head = unflatten(cfg, params)
    x = embed[tokens]
    pos = jnp.arange(tokens.shape[0])
    xnorms, ks, vs = [], [], []
    for lw in layers:
        xn = rmsnorm(x, lw["ln1"], cfg.eps)
        q = rope(xn @ lw["wq"], pos, cfg.n_heads, cfg.rope_base)
        k_pre = xn @ lw["wk"]
        k = rope(k_pre, pos, cfg.n_heads, cfg.rope_base)
        v = xn @ lw["wv"]
        x = x + attention_causal(q, k, v, cfg.n_heads) @ lw["wo"]
        xn2 = rmsnorm(x, lw["ln2"], cfg.eps)
        x = x + jax.nn.silu(xn2 @ lw["w1"]) @ lw["w2"]
        xnorms.append(xn)
        ks.append(k_pre)
        vs.append(v)
    logits = rmsnorm(x, ln_f, cfg.eps) @ lm_head
    return logits, jnp.stack(xnorms), jnp.stack(ks), jnp.stack(vs)


def _merge_softmax(o1, m1, l1, o2, m2, l2):
    """Merge two online-softmax partial states (per head).

    o: [H, dh] weighted sums; m: [H] running max; l: [H] normalizers.
    """
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    o = o1 * a1[:, None] + o2 * a2[:, None]
    l = l1 * a1 + l2 * a2
    return o, m, l


def _dense_attn_partial(q, k, v, n_heads, valid):
    """Partial online-softmax attention state of q against (k, v) rows with
    mask ``valid`` [N] (bool). q: [d]; k,v: [N, d]. Returns (o, m, l)."""
    n, d = k.shape
    dh = d // n_heads
    qh = q.reshape(n_heads, dh)
    kh = k.reshape(n, n_heads, dh)
    vh = v.reshape(n, n_heads, dh)
    scores = jnp.einsum("hd,nhd->hn", qh, kh) / jnp.sqrt(float(dh))
    scores = jnp.where(valid[None, :], scores, -1e30)
    m = jnp.max(scores, axis=1)
    m = jnp.maximum(m, -1e30)  # all-masked guard
    p = jnp.exp(scores - m[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    l = jnp.sum(p, axis=1)
    o = jnp.einsum("hn,nhd->hd", p, vh)
    return o, m, l


def decode_full(cfg: ModelConfig, params, token, pos, k_buf, v_buf):
    """One decode step against a full-precision cache.

    token: [] i32; pos: [] i32 (number of tokens already cached);
    k_buf/v_buf: [L, max_seq, d] with post-RoPE keys, rows >= pos invalid.

    Returns (logits [V], k_new [L, d] post-RoPE, v_new [L, d]).
    Rust writes k_new/v_new into row ``pos`` of its buffers.
    """
    embed, layers, ln_f, lm_head = unflatten(cfg, params)
    x = embed[token]
    k_news, v_news = [], []
    idx = jnp.arange(cfg.max_seq)
    for li, lw in enumerate(layers):
        xn = rmsnorm(x.reshape(1, -1), lw["ln1"], cfg.eps)[0]
        posv = pos.reshape(1)
        q = rope((xn @ lw["wq"]).reshape(1, -1), posv, cfg.n_heads, cfg.rope_base)[0]
        k_new = rope((xn @ lw["wk"]).reshape(1, -1), posv, cfg.n_heads, cfg.rope_base)[0]
        v_new = xn @ lw["wv"]
        # Attention over cached rows [0,pos) plus the new token itself.
        o1, m1, l1 = _dense_attn_partial(q, k_buf[li], v_buf[li], cfg.n_heads, idx < pos)
        o2, m2, l2 = _dense_attn_partial(
            q, k_new.reshape(1, -1), v_new.reshape(1, -1), cfg.n_heads,
            jnp.ones((1,), bool),
        )
        o, _m, l = _merge_softmax(o1, m1, l1, o2, m2, l2)
        attn = (o / l[:, None]).reshape(-1)
        x = x + attn @ lw["wo"]
        xn2 = rmsnorm(x.reshape(1, -1), lw["ln2"], cfg.eps)[0]
        x = x + jax.nn.silu(xn2 @ lw["w1"]) @ lw["w2"]
        k_news.append(k_new)
        v_news.append(v_new)
    logits = rmsnorm(x.reshape(1, -1), ln_f, cfg.eps)[0] @ lm_head
    return logits, jnp.stack(k_news), jnp.stack(v_news)


def decode_cskv(
    cfg: ModelConfig,
    params,
    ak, bk, av, bv,           # factors: ak/av [L, d, r]; bk/bv [L, r, d]
    token, n, win_len,        # scalars i32: tokens so far / window fill
    ck_buf, cv_buf,           # [L, max_seq, r] compressed history
    win_k, win_v,             # [L, win, d] (win_k pre-RoPE), rolling window
    win_pos,                  # [L, win] i32 absolute positions of window rows
):
    """One CSKV bi-branch decode step (§2.1, Figure 1b).

    The historical branch (`ck_buf` rows `[0, n - win_len)`) is attended
    through the fused Pallas kernel `bibranch_attn`: tiles of C are
    reconstructed as K̂ = C·B in fast memory and folded into an online
    softmax, so K̂ never materializes in slow memory. The window branch is
    dense and exact.

    Returns (logits [V], ck_new [L,r], cv_new [L,r], k_new [L,d] pre-RoPE,
    v_new [L,d]). Rust appends the ck/cv rows and rolls the window.
    """
    embed, layers, ln_f, lm_head = unflatten(cfg, params)
    x = embed[token]
    ck_news, cv_news, k_news, v_news = [], [], [], []
    hist = n - win_len  # rows of compressed history to attend
    for li, lw in enumerate(layers):
        xn = rmsnorm(x.reshape(1, -1), lw["ln1"], cfg.eps)[0]
        posv = n.reshape(1)
        q = rope((xn @ lw["wq"]).reshape(1, -1), posv, cfg.n_heads, cfg.rope_base)[0]
        k_new = xn @ lw["wk"]  # pre-RoPE (the window stores pre-RoPE keys)
        v_new = xn @ lw["wv"]
        # L1 kernel: compressed features for the new token.
        ck_new = lowrank_proj.project(xn.reshape(1, -1), ak[li])[0]
        cv_new = lowrank_proj.project(xn.reshape(1, -1), av[li])[0]

        # --- historical branch: fused reconstruct+attend over C·B -------
        o1, m1, l1 = bibranch_attn.hist_attention(
            q, ck_buf[li], bk[li], cv_buf[li], bv[li],
            hist, cfg.n_heads, cfg.rope_base,
        )
        # --- window branch (dense, exact) --------------------------------
        widx = jnp.arange(win_k.shape[1])
        wvalid = widx < win_len
        wk_roped = rope(win_k[li], win_pos[li], cfg.n_heads, cfg.rope_base)
        o2, m2, l2 = _dense_attn_partial(q, wk_roped, win_v[li], cfg.n_heads, wvalid)
        # --- the new token attends to itself ------------------------------
        k_self = rope(k_new.reshape(1, -1), posv, cfg.n_heads, cfg.rope_base)
        o3, m3, l3 = _dense_attn_partial(
            q, k_self, v_new.reshape(1, -1), cfg.n_heads, jnp.ones((1,), bool)
        )
        o, m_, l = _merge_softmax(o1, m1, l1, o2, m2, l2)
        o, m_, l = _merge_softmax(o, m_, l, o3, m3, l3)
        attn = (o / l[:, None]).reshape(-1)

        x = x + attn @ lw["wo"]
        xn2 = rmsnorm(x.reshape(1, -1), lw["ln2"], cfg.eps)[0]
        x = x + jax.nn.silu(xn2 @ lw["w1"]) @ lw["w2"]
        ck_news.append(ck_new)
        cv_news.append(cv_new)
        k_news.append(k_new)
        v_news.append(v_new)
    logits = rmsnorm(x.reshape(1, -1), ln_f, cfg.eps)[0] @ lm_head
    return (
        logits,
        jnp.stack(ck_news),
        jnp.stack(cv_news),
        jnp.stack(k_news),
        jnp.stack(v_news),
    )


# --------------------------------------------------------------------------
# Jitted entry points for AOT lowering
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig):
    return jax.jit(partial(train_step, cfg))


def make_prefill(cfg: ModelConfig):
    return jax.jit(partial(prefill, cfg))


def make_decode_full(cfg: ModelConfig):
    return jax.jit(partial(decode_full, cfg))


def make_decode_cskv(cfg: ModelConfig):
    return jax.jit(partial(decode_cskv, cfg))
