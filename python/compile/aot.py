"""AOT lowering: JAX → HLO **text** artifacts + manifest.

``make artifacts`` runs this once; afterwards the Rust binary is fully
self-contained (Python never touches the request path).

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that the published ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py there.

Artifacts (for the TINY config):

* ``train_step.hlo.txt``   — one Adam step, batch×seq fixed.
* ``prefill.hlo.txt``      — exact prefill over max_seq tokens, returning
  logits + per-layer xnorm/K(pre-RoPE)/V streams.
* ``decode_full.hlo.txt``  — one decode step, full-precision cache.
* ``decode_cskv_r{r}.hlo.txt`` — one CSKV bi-branch decode step at
  compressed rank r (one artifact per compression ratio; the paper's 50%
  and 80% settings by default).
* ``manifest.json``        — ordered input/output specs per executable +
  the embedded model config, consumed by ``rust/src/runtime/manifest.rs``.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Serving/training workload shapes (recorded in the manifest).
TRAIN_BATCH = 8
TRAIN_SEQ = 512
WINDOW = 32
# Compressed ranks exported by default: d_model=128 at keep 50% and 20%
# (the paper's 50% / 80% compression rows).
DEFAULT_RANKS = (64, 26)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def param_specs(cfg, prefix):
    return [spec(f"{prefix}.{n}", s) for n, s in M.param_shapes(cfg)]


def param_structs(cfg):
    return [f32(s) for _, s in M.param_shapes(cfg)]


def build_train_step(cfg):
    fn = M.make_train_step(cfg)
    p = param_structs(cfg)
    args = (
        p,
        p,
        p,
        i32(),
        i32((TRAIN_BATCH, TRAIN_SEQ)),
        i32((TRAIN_BATCH, TRAIN_SEQ)),
        f32((TRAIN_BATCH, TRAIN_SEQ)),
        f32(()),
    )
    lowered = fn.lower(*args)
    inputs = (
        param_specs(cfg, "params")
        + param_specs(cfg, "m")
        + param_specs(cfg, "v")
        + [
            spec("step", (), "i32"),
            spec("x", (TRAIN_BATCH, TRAIN_SEQ), "i32"),
            spec("y", (TRAIN_BATCH, TRAIN_SEQ), "i32"),
            spec("mask", (TRAIN_BATCH, TRAIN_SEQ)),
            spec("lr", ()),
        ]
    )
    outputs = (
        param_specs(cfg, "params")
        + param_specs(cfg, "m")
        + param_specs(cfg, "v")
        + [spec("loss", ())]
    )
    static = {"batch": TRAIN_BATCH, "seq": TRAIN_SEQ}
    return lowered, inputs, outputs, static


def build_prefill(cfg):
    fn = M.make_prefill(cfg)
    lowered = fn.lower(param_structs(cfg), i32((cfg.max_seq,)))
    L, T, d, V = cfg.n_layers, cfg.max_seq, cfg.d_model, cfg.vocab_size
    inputs = param_specs(cfg, "params") + [spec("tokens", (T,), "i32")]
    outputs = [
        spec("logits", (T, V)),
        spec("xnorms", (L, T, d)),
        spec("ks", (L, T, d)),
        spec("vs", (L, T, d)),
    ]
    return lowered, inputs, outputs, {"seq": T}


def build_decode_full(cfg):
    fn = M.make_decode_full(cfg)
    L, T, d, V = cfg.n_layers, cfg.max_seq, cfg.d_model, cfg.vocab_size
    lowered = fn.lower(
        param_structs(cfg), i32(), i32(), f32((L, T, d)), f32((L, T, d))
    )
    inputs = param_specs(cfg, "params") + [
        spec("token", (), "i32"),
        spec("pos", (), "i32"),
        spec("k_buf", (L, T, d)),
        spec("v_buf", (L, T, d)),
    ]
    outputs = [spec("logits", (V,)), spec("k_new", (L, d)), spec("v_new", (L, d))]
    return lowered, inputs, outputs, {"max_seq": T}


def build_decode_cskv(cfg, rank):
    fn = M.make_decode_cskv(cfg)
    L, T, d, V, W = cfg.n_layers, cfg.max_seq, cfg.d_model, cfg.vocab_size, WINDOW
    r = rank
    lowered = fn.lower(
        param_structs(cfg),
        f32((L, d, r)), f32((L, r, d)), f32((L, d, r)), f32((L, r, d)),
        i32(), i32(), i32(),
        f32((L, T, r)), f32((L, T, r)),
        f32((L, W, d)), f32((L, W, d)),
        i32((L, W)),
    )
    inputs = param_specs(cfg, "params") + [
        spec("ak", (L, d, r)),
        spec("bk", (L, r, d)),
        spec("av", (L, d, r)),
        spec("bv", (L, r, d)),
        spec("token", (), "i32"),
        spec("n", (), "i32"),
        spec("win_len", (), "i32"),
        spec("ck_buf", (L, T, r)),
        spec("cv_buf", (L, T, r)),
        spec("win_k", (L, W, d)),
        spec("win_v", (L, W, d)),
        spec("win_pos", (L, W), "i32"),
    ]
    outputs = [
        spec("logits", (V,)),
        spec("ck_new", (L, r)),
        spec("cv_new", (L, r)),
        spec("k_new", (L, d)),
        spec("v_new", (L, d)),
    ]
    return lowered, inputs, outputs, {"max_seq": T, "window": W, "rank": r}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--ranks", default=",".join(str(r) for r in DEFAULT_RANKS))
    ap.add_argument(
        "--skip-train", action="store_true", help="skip the (slow) train_step lowering"
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    cfg = M.TINY

    builders = {
        "prefill": lambda: build_prefill(cfg),
        "decode_full": lambda: build_decode_full(cfg),
    }
    for r in [int(x) for x in args.ranks.split(",") if x]:
        builders[f"decode_cskv_r{r}"] = (lambda rr: (lambda: build_decode_cskv(cfg, rr)))(r)
    if not args.skip_train:
        builders["train_step"] = lambda: build_train_step(cfg)

    manifest = {
        "format": "hlo-text-v1",
        "model": cfg.to_json_dict(),
        "executables": {},
    }
    for name, build in builders.items():
        lowered, inputs, outputs, static = build()
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        manifest["executables"][name] = {
            "file": fname,
            "inputs": inputs,
            "outputs": outputs,
            "static": static,
        }
        print(f"wrote {fname}: {len(text)} chars, {len(inputs)} inputs, {len(outputs)} outputs")

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest.json with {len(manifest['executables'])} executables")


if __name__ == "__main__":
    main()
