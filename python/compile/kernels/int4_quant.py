"""L1 Pallas kernel: KIVI-style int4 group fake-quantization (Table 5).

Quantizes one group of compressed features to asymmetric int4 and back:
per-channel statistics for keys, per-token for values (KIVI's layout).
The Rust layer owns the *packed storage* (`rust/src/compress/quant.rs`);
this kernel is the compute-path equivalent used inside quantized decode
variants, and its numerics are pinned against ``ref.py`` and the Rust
implementation (same scale/zero convention: 15 levels, asymmetric).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_per_channel(x_ref, o_ref):
    x = x_ref[...]
    lo = jnp.min(x, axis=0, keepdims=True)
    hi = jnp.max(x, axis=0, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / 15.0
    q = jnp.clip(jnp.round((x - lo) / scale), 0, 15)
    o_ref[...] = q * scale + lo


def _kernel_per_token(x_ref, o_ref):
    x = x_ref[...]
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / 15.0
    q = jnp.clip(jnp.round((x - lo) / scale), 0, 15)
    o_ref[...] = q * scale + lo


def fake_quant(x, axis: str):
    """Quantize-dequantize a ``[group, r]`` block.

    axis: "per_channel" (keys) or "per_token" (values).
    """
    kernel = _kernel_per_channel if axis == "per_channel" else _kernel_per_token
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
