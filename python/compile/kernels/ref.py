"""Pure-jnp oracles for every L1 kernel — the build-time correctness
signal (``python/tests/test_kernels.py`` pins kernels against these, and
the Rust engine is in turn pinned against the lowered artifacts)."""

import jax.numpy as jnp

NEG = -1e30


def project_ref(x, a):
    """Oracle for ``lowrank_proj.project``."""
    return x @ a


def rope_ref(x, positions, n_heads, base):
    """Rotate-half RoPE (identical to model.rope; duplicated so the kernel
    oracle has no dependency on the model module)."""
    t, dm = x.shape
    d = dm // n_heads
    half = d // 2
    xh = x.reshape(t, n_heads, d)
    theta = base ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / d)
    ang = positions.astype(jnp.float32)[:, None] * theta[None, :]
    sin = jnp.sin(ang)[:, None, :]
    cos = jnp.cos(ang)[:, None, :]
    a, b = xh[..., :half], xh[..., half:]
    rot = jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)
    return rot.reshape(t, dm)


def hist_attention_ref(q, ck, bk, cv, bv, hist, n_heads, rope_base):
    """Oracle for ``bibranch_attn.hist_attention``: materialize K̂/V̂ fully,
    then compute the same unnormalized online-softmax state."""
    max_seq = ck.shape[0]
    d = bk.shape[1]
    dh = d // n_heads
    khat = ck @ bk
    vhat = cv @ bv
    pos = jnp.arange(max_seq)
    khat = rope_ref(khat, pos, n_heads, rope_base)
    qh = q.reshape(n_heads, dh)
    kh = khat.reshape(max_seq, n_heads, dh)
    vh = vhat.reshape(max_seq, n_heads, dh)
    scores = jnp.einsum("nhd,hd->hn", kh, qh) / jnp.sqrt(float(dh))
    valid = (pos < hist)[None, :]
    scores = jnp.where(valid, scores, NEG)
    m = jnp.maximum(jnp.max(scores, axis=1), NEG)
    p = jnp.where(valid, jnp.exp(scores - m[:, None]), 0.0)
    l = jnp.sum(p, axis=1)
    o = jnp.einsum("hn,nhd->hd", p, vh)
    return o, m, l


def softmax_attention_ref(q, k, v, n_heads):
    """Plain single-query attention (for validating online-softmax merges):
    q: [d]; k, v: [n, d] (keys already RoPE'd). Returns [d]."""
    n, d = k.shape
    dh = d // n_heads
    qh = q.reshape(n_heads, dh)
    kh = k.reshape(n, n_heads, dh)
    vh = v.reshape(n, n_heads, dh)
    scores = jnp.einsum("nhd,hd->hn", kh, qh) / jnp.sqrt(float(dh))
    p = jnp.exp(scores - jnp.max(scores, axis=1, keepdims=True))
    p = p / jnp.sum(p, axis=1, keepdims=True)
    return jnp.einsum("hn,nhd->hd", p, vh).reshape(d)


def fake_quant_ref(x, axis: str):
    """Oracle for ``int4_quant.fake_quant`` (and the Rust quantizer)."""
    ax = 0 if axis == "per_channel" else 1
    lo = jnp.min(x, axis=ax, keepdims=True)
    hi = jnp.max(x, axis=ax, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / 15.0
    q = jnp.clip(jnp.round((x - lo) / scale), 0, 15)
    return q * scale + lo
