"""L1 Pallas kernel: fused bi-branch historical attention (§2.1, Fig. 1b).

The paper's decode hot spot: attend a single query against the compressed
history ``C`` (``[max_seq, r]``) *without materializing* the reconstructed
keys/values ``K̂ = C·B_K``, ``V̂ = C·B_V`` in slow memory.

Schedule (flash-attention-style, TPU mapping in DESIGN.md):

* ``B_K``/``B_V`` (``[r, d]``, tiny) and the query stay resident in VMEM.
* ``C`` streams HBM→VMEM in ``(BLOCK_N, r)`` tiles via the BlockSpec grid.
* Per tile: ``K̂_tile = C_tile · B_K`` on the MXU, RoPE at absolute
  positions (history row index == absolute position, since the compressed
  cache stores *every* token), per-head scores against ``q``, and an
  **online softmax** update of the ``(o, m, l)`` accumulators held in the
  output refs (the sequential TPU grid makes read-modify-write safe).
* Rows ``>= hist`` are masked (the window branch owns them).

The kernel returns the *partial* softmax state ``(o, m, l)`` so the L2
model can merge it with the dense window branch and the current token
(``model._merge_softmax``) — exactly how the paper's bi-branch concat is
realized without ever concatenating.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 64
NEG = -1e30


def _rope_tile(k, pos, n_heads, base):
    """Rotate-half RoPE on a [BN, d_model] tile at integer positions [BN]."""
    bn, dm = k.shape
    d = dm // n_heads
    half = d // 2
    kh = k.reshape(bn, n_heads, d)
    theta = base ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / d)
    ang = pos.astype(jnp.float32)[:, None] * theta[None, :]
    sin = jnp.sin(ang)[:, None, :]
    cos = jnp.cos(ang)[:, None, :]
    a, b = kh[..., :half], kh[..., half:]
    rot = jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)
    return rot.reshape(bn, dm)


def _make_kernel(n_heads: int, rope_base: float):
    def kernel(hist_ref, q_ref, ck_ref, bk_ref, cv_ref, bv_ref, o_ref, m_ref, l_ref):
        t = pl.program_id(0)

        @pl.when(t == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG)
            l_ref[...] = jnp.zeros_like(l_ref)

        hist = hist_ref[0]
        q = q_ref[...]  # [H, dh]
        dh = q.shape[-1]
        d = n_heads * dh

        # MXU: reconstruct this tile of keys/values from the low-rank cache.
        khat = ck_ref[...] @ bk_ref[...]  # [BN, d]
        vhat = cv_ref[...] @ bv_ref[...]  # [BN, d]

        # RoPE at absolute positions (= row indices of the full history).
        pos = t * BLOCK_N + jnp.arange(BLOCK_N)
        khat = _rope_tile(khat, pos, n_heads, rope_base)

        kh = khat.reshape(BLOCK_N, n_heads, dh)
        vh = vhat.reshape(BLOCK_N, n_heads, dh)
        scores = jnp.einsum("nhd,hd->hn", kh, q) / jnp.sqrt(float(dh))  # [H, BN]
        valid = (pos < hist)[None, :]
        scores = jnp.where(valid, scores, NEG)

        # Online softmax update.
        m_old = m_ref[...]
        l_old = l_ref[...]
        o_old = o_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(scores, axis=1))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(scores - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        l_ref[...] = l_old * alpha + jnp.sum(p, axis=1)
        o_ref[...] = o_old * alpha[:, None] + jnp.einsum("hn,nhd->hd", p, vh)
        m_ref[...] = m_new

    return kernel


def hist_attention(q, ck, bk, cv, bv, hist, n_heads, rope_base):
    """Partial attention of ``q`` over the compressed history.

    q: [d_model]; ck: [max_seq, rk]; bk: [rk, d]; cv: [max_seq, rv];
    bv: [rv, d]; hist: scalar i32 (valid history rows).

    Returns (o [H, dh], m [H], l [H]) — unnormalized online-softmax state.
    """
    max_seq, rk = ck.shape
    _, rv = cv.shape
    d = bk.shape[1]
    dh = d // n_heads
    assert max_seq % BLOCK_N == 0, f"max_seq {max_seq} must be a multiple of {BLOCK_N}"
    grid = (max_seq // BLOCK_N,)
    hist_arr = jnp.asarray(hist, jnp.int32).reshape(1)
    qh = q.reshape(n_heads, dh)
    kernel = _make_kernel(n_heads, rope_base)
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # hist scalar
            pl.BlockSpec((n_heads, dh), lambda i: (0, 0)),  # q resident
            pl.BlockSpec((BLOCK_N, rk), lambda i: (i, 0)),  # C_K streamed
            pl.BlockSpec((rk, d), lambda i: (0, 0)),        # B_K resident
            pl.BlockSpec((BLOCK_N, rv), lambda i: (i, 0)),  # C_V streamed
            pl.BlockSpec((rv, d), lambda i: (0, 0)),        # B_V resident
        ],
        out_specs=[
            pl.BlockSpec((n_heads, dh), lambda i: (0, 0)),
            pl.BlockSpec((n_heads,), lambda i: (0,)),
            pl.BlockSpec((n_heads,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_heads, dh), jnp.float32),
            jax.ShapeDtypeStruct((n_heads,), jnp.float32),
            jax.ShapeDtypeStruct((n_heads,), jnp.float32),
        ],
        interpret=True,
    )(hist_arr, qh, ck, bk, cv, bv)
    return o, m, l


def vmem_bytes(rk: int, rv: int, d: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set per grid step: two C tiles + both B
    factors + q + accumulators + two reconstructed tiles."""
    return dtype_bytes * (
        BLOCK_N * (rk + rv)      # streamed C tiles
        + (rk + rv) * d          # resident B factors
        + 3 * d                  # q + o accumulator (+ m/l, negligible)
        + 2 * BLOCK_N * d        # reconstructed K̂/V̂ tiles
    )
