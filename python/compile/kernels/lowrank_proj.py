"""L1 Pallas kernel: the channel-shrinking projection ``C = X · A``.

This is the producer of the compressed cache (§2.1): every token's
attention input is projected from ``d_model`` to ``rank`` channels and the
*intermediate feature* is stored.

TPU mapping (DESIGN.md §Hardware-Adaptation): ``A`` (`[d, r]`, ≤ 64 KiB for
TinyLM) is pinned in VMEM for the whole kernel; ``X`` streams HBM→VMEM in
``(BLOCK_ROWS, d)`` tiles via the BlockSpec index map; each tile runs one
``[BLOCK_ROWS, d] × [d, r]`` MXU matmul. ``interpret=True`` is mandatory on
CPU (real-TPU lowering emits a Mosaic custom-call the CPU PJRT plugin
cannot execute).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 64


def _kernel(x_ref, a_ref, o_ref):
    # One row-tile of X against the VMEM-resident A.
    o_ref[...] = x_ref[...] @ a_ref[...]


def project(x, a):
    """``C = X · A`` with X ``[n, d]``, A ``[d, r]`` → ``[n, r]``.

    ``n`` need not divide BLOCK_ROWS; the tail tile is padded by Pallas.
    """
    n, d = x.shape
    d2, r = a.shape
    assert d == d2, f"shape mismatch {x.shape} @ {a.shape}"
    if n <= BLOCK_ROWS:
        # Single-tile fast path (decode: n == 1).
        return pl.pallas_call(
            _kernel,
            out_shape=jax.ShapeDtypeStruct((n, r), x.dtype),
            interpret=True,
        )(x, a)
    grid = (pl.cdiv(n, BLOCK_ROWS),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((d, r), lambda i: (0, 0)),  # A resident across tiles
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), x.dtype),
        interpret=True,
    )(x, a)


def vmem_bytes(d: int, r: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set per grid step (perf model for DESIGN.md):
    one X tile + resident A + one C tile."""
    return dtype_bytes * (BLOCK_ROWS * d + d * r + BLOCK_ROWS * r)
