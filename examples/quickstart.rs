//! Quickstart: compress a model's KV cache with CSKV and generate.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the full public API surface end-to-end on a small scale:
//! 1. load (or fall back from) TinyLM weights;
//! 2. collect calibration activations;
//! 3. ASVD-initialize + layer-wise fine-tune the low-rank factors (§2.2);
//! 4. generate with the bi-branch cache (§2.1) and compare memory + output
//!    against the uncompressed cache.

use std::sync::Arc;

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::data::corpus::{calibration_docs, CorpusConfig};
use cskv::data::{tasks, vocab};
use cskv::finetune::{build_factors, FinetuneConfig};
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, QuantMode};
use cskv::model::{engine::Engine, ModelWeights};
use cskv::util::prng::Pcg64;
use cskv::util::table::bytes;

fn main() -> anyhow::Result<()> {
    // 1. Model weights: use the pretrained checkpoint if present, else a
    //    random init (the mechanics are identical; accuracy is only
    //    meaningful with `make pretrain`).
    let wpath = cskv::runs_dir().join("tinylm.bin");
    let weights = match ModelWeights::load(&wpath) {
        Ok(w) => {
            println!("using trained weights {}", wpath.display());
            w
        }
        Err(_) => {
            println!("no trained weights — using random init (run `make pretrain` for real accuracy)");
            ModelWeights::init(&cskv::model::ModelConfig::tiny(), 7)
        }
    };
    let engine = Engine::new(Arc::new(weights));
    let cfg = engine.w.cfg.clone();

    // 2. Calibration activations (stands in for the paper's Pile subset).
    println!("collecting calibration activations…");
    let docs = calibration_docs(&CorpusConfig::default(), 16, 99);
    let calib = engine.collect_calibration(&docs, 2048, 1);

    // 3. Channel shrinking at 80% compression with ASVD init + recon FT.
    let plan = KvCompressionPlan::uniform(0.8);
    println!(
        "fine-tuning low-rank factors: keep {}/{} channels per K/V",
        plan.rank_k(cfg.d_model),
        cfg.d_model
    );
    let report = build_factors(
        &engine.w,
        &calib,
        plan,
        &FinetuneConfig {
            init: InitMethod::asvd_default(),
            steps: 200,
            ..Default::default()
        },
    );
    println!("layer-wise reconstruction loss (Eq. 2): {:.6}", report.final_total_loss);
    let factors = Arc::new(report.factors);

    // 4. Generate on a long-context retrieval prompt with both caches.
    let mut rng = Pcg64::new(42);
    let sample = tasks::line_retrieval_ctx(384, &mut rng);
    println!(
        "\nprompt: {} tokens; query: {}",
        sample.ctx_len,
        vocab::detokenize(&sample.prompt[sample.prompt.len() - 3..])
    );
    println!("expected answer: {}", vocab::detokenize(&sample.answer));

    let mut full = FullCache::new(cfg.n_layers, cfg.d_model);
    let (out_full, stats_full) = engine.generate(&sample.prompt, vocab::VALUE_LEN, &mut full);
    let mut cskv = CskvCache::new(
        Arc::clone(&factors),
        cfg.d_model,
        CskvConfig {
            window: 32,
            quant: QuantMode::None,
        },
    );
    let (out_cskv, stats_cskv) = engine.generate(&sample.prompt, vocab::VALUE_LEN, &mut cskv);

    println!(
        "\nfull cache   : {} | kv = {}",
        vocab::detokenize(&out_full),
        bytes(stats_full.kv_bytes_final)
    );
    println!(
        "cskv 80%     : {} | kv = {}  ({:.1}% saved)",
        vocab::detokenize(&out_cskv),
        bytes(stats_cskv.kv_bytes_final),
        (1.0 - stats_cskv.kv_bytes_final as f64 / stats_full.kv_bytes_final as f64) * 100.0
    );
    println!(
        "correct: full={} cskv={}",
        tasks::score_exact(&out_full, &sample.answer),
        tasks::score_exact(&out_cskv, &sample.answer),
    );
    Ok(())
}
