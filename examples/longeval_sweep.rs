//! LongEval sweep: every compression method across context lengths —
//! a fast, single-binary view of Table 1's qualitative story.
//!
//! ```bash
//! make pretrain   # once
//! cargo run --release --example longeval_sweep -- --samples 15 --ratio 0.8
//! ```

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::eval::experiments::{eval_cell, factors_for, Env, Method};
use cskv::eval::{EvalSet, Suite};
use cskv::finetune::recon::QatMode;
use cskv::kvcache::QuantMode;
use cskv::util::cli::Args;
use cskv::util::table::{acc, bytes, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let env = Env::load_default()?;
    let n = args.get_usize("samples", 15);
    let ratio = args.get_f64("ratio", 0.8);
    let seed = args.get_u64("seed", 70);

    let plan = KvCompressionPlan::uniform(ratio);
    let asvd_f = factors_for(&env, plan, InitMethod::asvd_default(), 0, QatMode::Off);
    let cskv_f = factors_for(&env, plan, InitMethod::asvd_default(), 250, QatMode::Off);
    let methods = vec![
        Method::Full,
        Method::StreamingLlm { ratio },
        Method::H2o { ratio },
        Method::Asvd { factors: asvd_f },
        Method::Cskv {
            factors: cskv_f,
            window: 32,
            quant: QuantMode::None,
        },
    ];

    let ctxs = args.get_list_usize("ctx", &[128, 256, 384, 500]);
    let mut header = vec!["method".to_string()];
    header.extend(ctxs.iter().map(|c| format!("acc@{c}")));
    header.extend(ctxs.iter().map(|c| format!("agree@{c}")));
    header.push("mean kv".into());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!(
            "LongEval at {}% compression ({n} samples/cell; agree = matches full-cache output)",
            (ratio * 100.0) as u32
        ),
        &hdr,
    );

    let sets: Vec<(Suite, EvalSet)> = ctxs
        .iter()
        .map(|&ctx| {
            let s = Suite::LongEval { ctx };
            let set = EvalSet::build(&env.engine, s.sample_set(n, seed));
            (s, set)
        })
        .collect();

    for m in &methods {
        let mut accs = Vec::new();
        let mut agrees = Vec::new();
        let mut kv = 0.0;
        for (suite, set) in &sets {
            let r = eval_cell(&env, set, suite, m);
            kv = r.mean_kv_bytes;
            accs.push(acc(r.accuracy()));
            agrees.push(acc(r.agreement()));
        }
        let mut cells = vec![m.label().to_string()];
        cells.extend(accs);
        cells.extend(agrees);
        cells.push(bytes(kv as usize));
        t.row(&cells);
    }
    t.print();
    println!(
        "expected shape (paper Table 1 @80%): CSKV ≈ full ≫ ASVD ≈ H2O ≈ StreamingLLM,\n\
         with token pruning failing because evicted lines are unrecoverable."
    );
    Ok(())
}
