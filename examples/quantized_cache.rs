//! Quantized cache demo — CSKV + KIVI-style int4 (Table 5's headline):
//! 80% channel shrinking × int4 ⇒ ~95%+ total KV reduction, with QAT
//! keeping quality while PTQ collapses.
//!
//! ```bash
//! make pretrain   # once
//! cargo run --release --example quantized_cache
//! ```

use std::sync::Arc;

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::data::{tasks, vocab};
use cskv::eval::experiments::{factors_for, Env};
use cskv::eval::{EvalSet, Suite};
use cskv::finetune::recon::QatMode;
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::util::cli::Args;
use cskv::util::prng::Pcg64;
use cskv::util::table::{acc, bytes, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let env = Env::load_default()?;
    let cfg = env.engine.w.cfg.clone();
    let ratio = args.get_f64("ratio", 0.8);
    let n = args.get_usize("samples", 15);
    let plan = KvCompressionPlan::uniform(ratio);

    println!("building factor sets (plain + QAT)…");
    let f_plain = factors_for(&env, plan, InitMethod::asvd_default(), 250, QatMode::Off);
    let f_qat = factors_for(&env, plan, InitMethod::asvd_default(), 250, QatMode::Int4);

    let suite = Suite::LongEval { ctx: 384 };
    let set = EvalSet::build(&env.engine, suite.sample_set(n, 77));

    let mut t = Table::new(
        &format!("CSKV {}% + int4 (window = residual = 32, {n} samples)", (ratio * 100.0) as u32),
        &["config", "accuracy", "agree-vs-full", "kv bytes"],
    );
    type F = Box<dyn FnMut() -> Box<dyn KvCachePolicy>>;
    let rows: Vec<(&str, F)> = vec![
        ("full fp32", {
            let c = cfg.clone();
            Box::new(move || Box::new(FullCache::new(c.n_layers, c.d_model)) as Box<dyn KvCachePolicy>)
        }),
        ("cskv fp32 (None)", {
            let c = cfg.clone();
            let f = Arc::clone(&f_plain);
            Box::new(move || {
                Box::new(CskvCache::new(Arc::clone(&f), c.d_model, CskvConfig { window: 32, quant: QuantMode::None }))
                    as Box<dyn KvCachePolicy>
            })
        }),
        ("cskv int4 PTQ", {
            let c = cfg.clone();
            let f = Arc::clone(&f_plain);
            Box::new(move || {
                Box::new(CskvCache::new(Arc::clone(&f), c.d_model, CskvConfig { window: 32, quant: QuantMode::Int4 }))
                    as Box<dyn KvCachePolicy>
            })
        }),
        ("cskv int4 QAT", {
            let c = cfg.clone();
            let f = Arc::clone(&f_qat);
            Box::new(move || {
                Box::new(CskvCache::new(Arc::clone(&f), c.d_model, CskvConfig { window: 32, quant: QuantMode::Int4 }))
                    as Box<dyn KvCachePolicy>
            })
        }),
    ];
    for (label, mut factory) in rows {
        let r = set.eval(&env.engine, &mut factory);
        t.row(&[
            label.to_string(),
            acc(r.accuracy()),
            acc(r.agreement()),
            bytes(r.mean_kv_bytes as usize),
        ]);
    }
    t.print();

    // Show a concrete near-miss failure the paper describes ("4244" vs
    // "42440") by rendering one PTQ output.
    let mut rng = Pcg64::new(5);
    let s = tasks::line_retrieval_ctx(384, &mut rng);
    let mut ptq = CskvCache::new(Arc::clone(&f_plain), cfg.d_model, CskvConfig { window: 32, quant: QuantMode::Int4 });
    let (out, _) = env.engine.generate(&s.prompt, vocab::VALUE_LEN, &mut ptq);
    println!(
        "sample failure-case inspection — expected {:?}, PTQ generated {:?}",
        vocab::detokenize(&s.answer),
        vocab::detokenize(&out)
    );
    Ok(())
}
