//! END-TO-END DRIVER (recorded in EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real small workload:
//!
//! 1. **Train** TinyLM through the PJRT `train_step` artifact (L2 JAX
//!    fwd/bwd lowered once; the L3 Rust trainer drives the loop and logs
//!    the loss curve).
//! 2. **Compress**: calibrate → ASVD init → layer-wise reconstruction
//!    fine-tuning (§2.2).
//! 3. **Serve** batched long-context retrieval requests through the
//!    coordinator with (a) the full cache and (b) the CSKV bi-branch
//!    cache under the same KV budget, reporting accuracy, latency
//!    percentiles, throughput and KV memory.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_and_serve -- --steps 120
//! ```
//! (`--steps 0` reuses runs/tinylm.bin if present.)

use std::sync::Arc;

use cskv::compress::{InitMethod, KvCompressionPlan};
use cskv::coordinator::server::{BackendFactory, Setup};
use cskv::coordinator::{Coordinator, CoordinatorConfig, RustSequenceBackend};
use cskv::data::corpus::{calibration_docs, CorpusConfig};
use cskv::data::tasks;
use cskv::eval::experiments::{factors_for, Env};
use cskv::finetune::recon::QatMode;
use cskv::kvcache::{CskvCache, CskvConfig, FullCache, KvCachePolicy, QuantMode};
use cskv::model::ModelWeights;
use cskv::runtime::trainer::{TrainConfig, Trainer};
use cskv::runtime::Runtime;
use cskv::util::cli::Args;
use cskv::util::prng::Pcg64;
use cskv::util::table::{bytes, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 120);
    let wpath = cskv::runs_dir().join("tinylm.bin");

    // ---- 1. TRAIN (L3 drives the AOT train_step) -----------------------
    if steps > 0 || !wpath.exists() {
        let rt = Runtime::load_default()?;
        let mut trainer = Trainer::new(&rt, args.get_u64("seed", 1234))?;
        println!("training TinyLM for {steps} steps through PJRT train_step…");
        let losses = trainer.train(&TrainConfig {
            steps: steps.max(30),
            lr: args.get_f64("lr", 3e-3) as f32,
            seed: args.get_u64("seed", 1234),
            log_every: 20,
        })?;
        trainer.weights.save(&wpath)?;
        println!(
            "loss curve: {:.3} → {:.3} over {} steps (full curve in runs/pretrain_loss.csv)",
            losses[0],
            losses.last().unwrap(),
            losses.len()
        );
        let csv: String = losses.iter().enumerate().map(|(i, l)| format!("{i},{l}\n")).collect();
        std::fs::write(cskv::runs_dir().join("pretrain_loss.csv"), format!("step,loss\n{csv}"))?;
    } else {
        println!("reusing existing {}", wpath.display());
    }

    // ---- 2. COMPRESS -----------------------------------------------------
    let env = Env::load_default()?;
    let plan = KvCompressionPlan::uniform(args.get_f64("ratio", 0.8));
    println!(
        "building CSKV factors: keep {}/{} channels, ASVD init + recon fine-tune…",
        plan.rank_k(env.d_model()),
        env.d_model()
    );
    let factors = factors_for(&env, plan, InitMethod::asvd_default(), 250, QatMode::Off);

    // Sanity: reconstruction quality on calibration data.
    let docs = calibration_docs(&CorpusConfig::default(), 4, 5);
    let calib = env.engine.collect_calibration(&docs, 1024, 2);
    for (li, lf) in factors.layers.iter().enumerate() {
        println!(
            "  layer {li}: rel K err {:.4}, rel V err {:.4}",
            lf.k.relative_error(&calib[li], &env.engine.w.layers[li].wk),
            lf.v.relative_error(&calib[li], &env.engine.w.layers[li].wv)
        );
    }

    // ---- 3. SERVE --------------------------------------------------------
    let n_req = args.get_usize("requests", 24);
    let ctx = args.get_usize("ctx", 384);
    let kv_budget = env.engine.w.cfg.kv_bytes_full(512) * 2; // ~2 full seqs
    let weights: Arc<ModelWeights> = Arc::clone(&env.engine.w);

    let mk_setup = |use_cskv: bool| -> Setup {
        let w = Arc::clone(&weights);
        let f = Arc::clone(&factors);
        Box::new(move || {
            let engine = cskv::model::engine::Engine::new(w);
            let factory: BackendFactory = Box::new(move || {
                let c = engine.w.cfg.clone();
                let policy: Box<dyn KvCachePolicy> = if use_cskv {
                    Box::new(CskvCache::new(
                        Arc::clone(&f),
                        c.d_model,
                        CskvConfig { window: 32, quant: QuantMode::None },
                    ))
                } else {
                    Box::new(FullCache::new(c.n_layers, c.d_model))
                };
                Ok(Box::new(RustSequenceBackend::new(engine.clone(), policy)))
            });
            Ok(factory)
        })
    };

    let mut t = Table::new(
        &format!("serving {n_req} retrieval requests (ctx≈{ctx}, KV budget {})", bytes(kv_budget)),
        &["cache", "accuracy", "tok/s", "p50 ttft", "p95 ttft", "max conc.", "kv peak"],
    );
    for (label, use_cskv) in [("full", false), ("CSKV 80%", true)] {
        let coord = Coordinator::start(
            mk_setup(use_cskv),
            CoordinatorConfig { max_batch: 16, kv_budget_bytes: Some(kv_budget), ..Default::default() },
        );
        let mut rng = Pcg64::new(31);
        let mut answers = Vec::new();
        let rxs: Vec<_> = (0..n_req)
            .map(|_| {
                let s = tasks::line_retrieval_ctx(ctx, &mut rng);
                answers.push(s.answer.clone());
                coord.submit(s.prompt, cskv::data::vocab::VALUE_LEN)
            })
            .collect();
        let mut correct = 0;
        for (rx, ans) in rxs.into_iter().zip(answers) {
            let r = rx.recv()?;
            if tasks::score_exact(&r.tokens, &ans) {
                correct += 1;
            }
        }
        let snap = coord.shutdown();
        t.row(&[
            label.to_string(),
            format!("{:.2}", correct as f64 / n_req as f64),
            format!("{:.1}", snap.throughput_tok_s()),
            format!("{:.3}s", snap.ttft_s.percentile(50.0)),
            format!("{:.3}s", snap.ttft_s.percentile(95.0)),
            snap.active_peak.to_string(),
            bytes(snap.kv_bytes_peak),
        ]);
    }
    t.print();
    t.save_csv(&cskv::runs_dir().join("e2e_serving.csv"))?;
    println!("E2E complete — recorded in runs/e2e_serving.csv (see EXPERIMENTS.md §E2E)");
    Ok(())
}
